//! `repro` CLI subcommands.
//!
//! ```text
//! repro fig2 [--series]               # Fig 2 energy breakdown
//! repro exp1 [--model XC7S25] [--csv PATH] [--threads N]
//! repro exp2 [--step 0.01] [--csv PATH] [--config FILE] [--threads N]
//! repro exp3 [--step 0.01] [--csv PATH] [--threads N]
//! repro validate [--period 40] [--threads N]
//! repro exp4 [--items 2000] [--period 40] [--seed 4] [--csv PATH] [--threads N]
//! repro gen-trace [--kind bursty-iot] [--gaps 256] [--period 40] [--seed 1]
//!                 [--out PATH]        # synthesize a workloads/ gap trace
//! repro tune --policy windowed-quantile --trace workloads/bursty_iot.csv
//!            [--search grid|random|halving] [--objective energy|lifetime]
//!            [--budget 64] [--split 0.7] [--max-late-rate R] [--seed 0]
//!            [--csv PATH] [--emit PATH] [--threads N]
//!                                     # auto-search PolicyParams on a trace
//! repro train [--trace workloads/bursty_iot.csv] [--budget 8] [--split 0.7]
//!             [--objective energy|lifetime] [--max-late-rate R] [--seed 0]
//!             [--quick] [--csv PATH] [--emit PATH] [--threads N]
//!                                     # fit the bandit's action table offline
//! repro exp5 [--requests 250] [--sources 4] [--period 40] [--seed 5]
//!            [--csv PATH] [--threads N]
//!                                     # scheduling policy × offered load grid
//! repro serve [--policy idle-waiting] [--period 40] [--requests 100]
//!             [--variant int8] [--arrival poisson] [--keep-alive]
//!             [--sources N] [--max-queue N] [--deadline-slack-ms T]
//!             [--quick]               # --sources >= 2: multi-client coordinator
//!             [--timeout-ms T] [--ema-alpha A] [--window W] [--quantile Q]
//!             [--saving m12] [--components K] [--table CELLS]
//!             [--params-file PATH]    # per-policy tunables / tuned fragment
//! repro plan --period 75              # policy recommendation
//! repro fleet [--devices 1000] [--steps 256] [--requests 2000]
//!             [--placement round-robin] [--trace FILE] [--period MS]
//!             [--seed S] [--deadline-ms T] [--fault-config-rate R]
//!             [--retry-max N] [--backoff-ms T] [--quick] [--csv PATH]
//!             [--config FILE] [--threads N]
//!                                     # fleet-scale DES + wake-placement routing
//! repro faults [--items 2000] [--period 40] [--seed 250] [--retry-max 3]
//!              [--backoff-ms 10] [--quick] [--csv PATH] [--config FILE]
//!              [--threads N]          # fault rate × policy robustness sweep
//! repro bench [--json PATH] [--quick] [--filter NAME] [--items N] [--threads N]
//!                                     # in-process perf benchmarks, optionally as JSON
//! repro bench-compare <before.json> <after.json> [--out PATH] [--max-regress 0.25]
//!                                     # before/after markdown table + regression gate
//! repro all [--threads N]             # every experiment, paper order
//! ```
//!
//! Every sweep command accepts `--threads N` (0 or absent = all cores);
//! results are byte-identical at any thread count.

use anyhow::{bail, Context, Result};

use crate::cli::args::Args;
use crate::config::loader::{load_file, paper_default, SimConfig};
use crate::config::schema::{parse_saving, FpgaModel, PolicyParams, PolicySpec};
use crate::coordinator::requests;
use crate::coordinator::server::{serve, ServerConfig};
use crate::coordinator::tracegen::{self, TraceKind};
use crate::energy::analytical::Analytical;
use crate::energy::crossover;
use crate::experiments::{exp1, exp2, exp3, fig2, validation};
use crate::runner::SweepRunner;
use crate::runtime::inference::Variant;
use crate::strategies::strategy::build_with;
use crate::util::units::Duration;

/// Top-level usage text (printed for `repro`, `repro help`, errors).
pub const USAGE: &str = "\
repro — reproduction of 'Idle is the New Sleep' (CS.AR 2024)

USAGE: repro <command> [options]

COMMANDS:
  fig2        Fig 2: energy breakdown of a workload item
  exp1        Experiment 1 (Fig 7): configuration-parameter sweep
  exp2        Experiment 2 (Figs 8-9): Idle-Waiting vs On-Off
  exp3        Experiment 3 (Table 3, Figs 10-11): idle power-saving
  exp4        Online gap policies \u{d7} tunables \u{d7} arrival processes (\u{a7}7 future work)
  exp5        Multi-client scheduling \u{d7} offered load on the serving coordinator
  gen-trace   Synthesize a gap-trace workload file (bursty-iot, diurnal-poisson, onoff-mmpp)
  tune        Auto-search PolicyParams for a policy on a gap trace (grid/random/halving)
  train       Fit the contextual bandit's per-cell action table offline on a gap trace
  validate    \u{a7}5.3 validation: analytical model vs discrete-event sim
  ablate      ablations: flash floor, power-on transient, multi-accel
  multi       event-driven multi-accelerator simulation (\u{a7}4.2 extension)
  serve       Duty-cycle serving: 1 source = REAL LSTM inference via PJRT;
              --sources >= 2 = the event-driven multi-client coordinator
  plan        Recommend a strategy for a given request period
  fleet       Fleet-scale DES: 100k+ devices, streaming aggregates, wake-placement routing
  faults      Robustness sweep: configuration fault rate \u{d7} gap policy under retries
  bench       Time the hot paths (DES, sweeps, tuner); --json emits {name, iters, ns_per_iter, throughput}
  bench-compare  Diff two bench --json recordings: speedup table + regression verdict
  all         Run every experiment in paper order

Run 'repro <command> --help' for options.";

fn load_config(args: &Args) -> Result<SimConfig> {
    match args.str_opt("config") {
        Some(path) => load_file(path).with_context(|| format!("loading config {path}")),
        None => Ok(paper_default()),
    }
}

fn maybe_write_csv(args: &Args, csv: crate::util::csv::Csv) -> Result<()> {
    if let Some(path) = args.str_opt("csv") {
        csv.write_to(path).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `--threads N` → a sweep runner; 0 or absent = all available cores.
/// Sweep output is byte-identical at any thread count, so the default is
/// always safe.
fn sweep_runner(args: &Args) -> Result<SweepRunner> {
    Ok(match args.u64_opt("threads")?.unwrap_or(0) {
        0 => SweepRunner::auto(),
        n => SweepRunner::new(n as usize),
    })
}

/// Overlay the per-policy tunable flags (`--timeout-ms`, `--ema-alpha`,
/// `--window`, `--quantile`, `--components`, `--table`, `--saving`) onto
/// the config's `policy_params`, then range-check the result — the same
/// validation the config loader applies, so a bad flag fails with the
/// same actionable message instead of reaching a sweep.
fn policy_params_from_args(args: &Args, base: PolicyParams) -> Result<PolicyParams> {
    use crate::config::schema::PolicyTable;

    let mut params = base;
    if let Some(ms) = args.f64_opt("timeout-ms")? {
        params.timeout = Some(Duration::from_millis(ms));
    }
    if let Some(a) = args.f64_opt("ema-alpha")? {
        params.ema_alpha = a;
    }
    if let Some(w) = args.u64_opt("window")? {
        params.window = w as usize;
    }
    if let Some(q) = args.f64_opt("quantile")? {
        params.quantile = q;
    }
    if let Some(k) = args.u64_opt("components")? {
        params.components = k as usize;
    }
    if let Some(text) = args.str_opt("table") {
        params.table = Some(PolicyTable::parse(text).with_context(|| {
            format!(
                "--table must be {} letters from {{i, o, t}} (got {} chars)",
                PolicyTable::CELLS,
                text.chars().count()
            )
        })?);
    }
    if let Some(name) = args.str_opt("saving") {
        params.saving = parse_saving(name)
            .with_context(|| format!("unknown saving level '{name}' (expected baseline, m1 or m12)"))?;
    }
    params.validate().map_err(anyhow::Error::msg)?;
    Ok(params)
}

/// `--step` must be a positive, finite millisecond value — reject it at
/// the CLI boundary with a readable error instead of hitting the grid's
/// programmer-error assert.
fn step_arg(args: &Args, default: f64) -> Result<f64> {
    let step = args.f64_opt("step")?.unwrap_or(default);
    if !(step.is_finite() && step > 0.0) {
        bail!("--step must be a positive number of milliseconds (got {step})");
    }
    Ok(step)
}

/// Dispatch one CLI invocation (argv without the binary name).
pub fn run(argv: &[String]) -> Result<()> {
    let Some(command) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match command.as_str() {
        "fig2" => cmd_fig2(rest),
        "exp1" => cmd_exp1(rest),
        "exp2" => cmd_exp2(rest),
        "exp3" => cmd_exp3(rest),
        "exp4" => cmd_exp4(rest),
        "exp5" => cmd_exp5(rest),
        "gen-trace" => cmd_gen_trace(rest),
        "tune" => cmd_tune(rest),
        "train" => cmd_train(rest),
        "validate" => cmd_validate(rest),
        "ablate" => cmd_ablate(rest),
        "multi" => cmd_multi(rest),
        "serve" => cmd_serve(rest),
        "plan" => cmd_plan(rest),
        "fleet" => cmd_fleet(rest),
        "faults" => cmd_faults(rest),
        "bench" => cmd_bench(rest),
        "bench-compare" => cmd_bench_compare(rest),
        "all" => cmd_all(rest),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn help_and_done(args: &Args, name: &str) -> bool {
    if args.flag("help") {
        println!("options for '{name}':\n{}", args.help());
        true
    } else {
        false
    }
}

fn cmd_fig2(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[("series", false), ("threads", true), ("help", false)])?;
    if help_and_done(&args, "fig2") {
        return Ok(());
    }
    print!("{}", fig2::run().render());
    if args.flag("series") {
        let runner = sweep_runner(&args)?;
        println!("\nreconstruction sensitivity (config share vs assumed single-SPI clock):");
        for (freq, share) in fig2::share_series(&runner) {
            println!("  {freq:>5.1} MHz → {:.2}%", share * 100.0);
        }
    }
    Ok(())
}

fn cmd_exp1(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            ("model", true),
            ("csv", true),
            ("full", false),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "exp1") {
        return Ok(());
    }
    let model = match args.str_opt("model") {
        Some(name) => FpgaModel::parse(name)
            .with_context(|| format!("unknown FPGA model '{name}'"))?,
        None => FpgaModel::Xc7s15,
    };
    let result = exp1::run_threaded(model, &sweep_runner(&args)?);
    if args.flag("full") {
        print!("{}", result.render_fig7());
    }
    print!("{}", result.render_summary());
    maybe_write_csv(&args, result.to_csv())
}

fn cmd_exp2(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            ("step", true),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "exp2") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let step = step_arg(&args, 0.01)?;
    let result = exp2::run_threaded(&config, step, &sweep_runner(&args)?);
    print!("{}", result.render_figs());
    print!("{}", result.render_summary(&config));
    maybe_write_csv(&args, result.to_csv())
}

fn cmd_exp3(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            ("step", true),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "exp3") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let step = step_arg(&args, 0.01)?;
    let result = exp3::run_threaded(&config, step, &sweep_runner(&args)?);
    print!("{}", result.render_table3());
    print!("{}", result.render_figs());
    print!("{}", result.render_summary());
    maybe_write_csv(&args, result.to_csv())
}

fn cmd_exp4(argv: &[String]) -> Result<()> {
    use crate::experiments::exp4_policies::{self, Exp4Config};

    let args = Args::parse(
        argv,
        &[
            ("items", true),
            ("period", true),
            ("seed", true),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "exp4") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let defaults = Exp4Config::default();
    let e4 = Exp4Config {
        items: args.u64_opt("items")?.unwrap_or(defaults.items),
        period_ms: args
            .f64_opt("period")?
            .unwrap_or_else(|| config.workload.arrival.mean_period().millis()),
        seed: args.u64_opt("seed")?.unwrap_or(defaults.seed),
    };
    let result = exp4_policies::run_threaded(&config, &e4, &sweep_runner(&args)?)
        .context("loading the configured arrival trace for exp4")?;
    print!("{}", result.render());
    maybe_write_csv(&args, result.to_csv())
}

fn cmd_exp5(argv: &[String]) -> Result<()> {
    use crate::experiments::exp5_serving::{self, Exp5Config};

    let args = Args::parse(
        argv,
        &[
            ("requests", true),
            ("sources", true),
            ("period", true),
            ("seed", true),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "exp5") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let defaults = Exp5Config::default();
    let requests = args.u64_opt("requests")?.unwrap_or(defaults.requests as u64) as usize;
    if requests == 0 {
        bail!("--requests must be at least 1");
    }
    let sources = match args.u64_opt("sources")? {
        Some(0) => bail!("--sources must be at least 1"),
        Some(n) => n as usize,
        None => defaults.sources,
    };
    let period_ms = args.f64_opt("period")?.unwrap_or(defaults.period_ms);
    if !(period_ms.is_finite() && period_ms > 0.0) {
        bail!("--period must be a positive number of milliseconds (got {period_ms})");
    }
    let e5 = Exp5Config {
        requests,
        sources,
        period_ms,
        seed: args.u64_opt("seed")?.unwrap_or(defaults.seed),
    };
    let result = exp5_serving::run_threaded(&config, &e5, &sweep_runner(&args)?);
    print!("{}", result.render());
    maybe_write_csv(&args, result.to_csv())
}

fn cmd_gen_trace(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            ("kind", true),
            ("gaps", true),
            ("period", true),
            ("seed", true),
            ("out", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "gen-trace") {
        return Ok(());
    }
    let kind = match args.str_opt("kind") {
        Some(name) => TraceKind::parse(name).with_context(|| {
            format!(
                "unknown trace kind '{name}' (expected one of: {})",
                TraceKind::ALL.map(|k| k.name()).join(", ")
            )
        })?,
        None => TraceKind::BurstyIot,
    };
    let gaps = args.u64_opt("gaps")?.unwrap_or(256) as usize;
    if gaps == 0 {
        bail!("--gaps must be at least 1");
    }
    let period_ms = args.f64_opt("period")?.unwrap_or(40.0);
    if !(period_ms.is_finite() && period_ms > 0.0) {
        bail!("--period must be a positive number of milliseconds (got {period_ms})");
    }
    let seed = args.u64_opt("seed")?.unwrap_or(1);
    match args.str_opt("out") {
        Some(path) => {
            let values = tracegen::write_file(path, kind, gaps, period_ms, seed)
                .with_context(|| format!("writing trace {path}"))?;
            let mean = values.iter().sum::<f64>() / values.len() as f64;
            println!(
                "wrote {path}: {} {} gaps, nominal {period_ms} ms, seed {seed} (mean {:.2} ms)",
                values.len(),
                kind.name(),
                mean
            );
        }
        None => {
            let values = tracegen::generate(kind, gaps, period_ms, seed);
            print!("{}", tracegen::render(kind, &values, period_ms, seed));
        }
    }
    Ok(())
}

fn cmd_tune(argv: &[String]) -> Result<()> {
    use crate::tuner::{self, Objective, ObjectiveKind, SearchStrategy, TuneConfig};

    let args = Args::parse(
        argv,
        &[
            ("policy", true),
            ("trace", true),
            ("search", true),
            ("objective", true),
            ("budget", true),
            ("split", true),
            ("seed", true),
            ("max-late-rate", true),
            ("csv", true),
            ("emit", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "tune") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let spec = match args.str_opt("policy") {
        Some(name) => PolicySpec::parse(name)
            .with_context(|| format!("unknown policy '{name}'"))?,
        None => config.workload.policy,
    };
    let search = match args.str_opt("search") {
        Some(name) => SearchStrategy::parse(name).with_context(|| {
            format!(
                "unknown search '{name}' (expected one of: {})",
                SearchStrategy::ALL.map(|s| s.name()).join(", ")
            )
        })?,
        None => SearchStrategy::Halving,
    };
    let kind = match args.str_opt("objective") {
        Some(name) => ObjectiveKind::parse(name)
            .with_context(|| format!("unknown objective '{name}' (expected energy or lifetime)"))?,
        None => ObjectiveKind::Energy,
    };
    let max_late_rate = args.f64_opt("max-late-rate")?;
    if let Some(r) = max_late_rate {
        if !(r.is_finite() && (0.0..=1.0).contains(&r)) {
            bail!("--max-late-rate must be a fraction in [0, 1] (got {r})");
        }
    }
    // the trace: an explicit --trace file, or the config's own trace arrival
    let trace_path = match args.str_opt("trace") {
        Some(path) => path.to_string(),
        None => match &config.workload.arrival {
            crate::config::schema::ArrivalSpec::Trace { path, .. } => path.clone(),
            _ => bail!(
                "no trace to tune on: pass --trace <file> or use a config whose \
                 arrival_kind is 'trace'"
            ),
        },
    };
    // the trace is parsed once and shared: every DES evaluation slices
    // this Arc rather than copying the gap sequence
    let replay = requests::TraceReplay::from_file(&trace_path)
        .with_context(|| format!("loading gap trace {trace_path}"))?;
    let gaps = replay.shared_gaps();

    let tc = TuneConfig {
        spec,
        search,
        objective: Objective {
            kind,
            max_late_rate,
        },
        budget: args.u64_opt("budget")?.unwrap_or(TuneConfig::DEFAULT_BUDGET as u64) as usize,
        split: args.f64_opt("split")?.unwrap_or(TuneConfig::DEFAULT_SPLIT),
        seed: args.u64_opt("seed")?.unwrap_or(0),
    };
    let runner = sweep_runner(&args)?;
    println!(
        "tuning {} on {trace_path} ({} gaps): search {}, objective {}, budget {}",
        spec.name(),
        gaps.len(),
        tc.search,
        tc.objective.label(),
        tc.budget
    );
    let outcome = tuner::tune(&config, &tc, &gaps, &runner)
        .with_context(|| format!("tuning {} on {trace_path}", spec.name()))?;
    print!("{}", outcome.render());
    println!("apply: {}", tuner::flags_line(spec, &outcome.best));
    if let Some(path) = args.str_opt("emit") {
        std::fs::write(path, tuner::yaml_fragment(spec, &outcome.best))
            .with_context(|| format!("writing tuned params {path}"))?;
        println!("wrote {path}");
    }
    maybe_write_csv(&args, outcome.to_csv())
}

/// `repro train`: fit the contextual bandit's per-cell action table
/// offline on a gap trace (the `tune` sibling for a policy whose
/// deployment artifact is a trained table, not a searched knob value).
/// `--emit` writes the frozen `(alpha, table)` point as the same YAML
/// fragment surface `repro serve --params-file` and `repro multi` load.
fn cmd_train(argv: &[String]) -> Result<()> {
    use crate::tuner::{self, Objective, ObjectiveKind, TrainConfig};

    let args = Args::parse(
        argv,
        &[
            ("trace", true),
            ("objective", true),
            ("budget", true),
            ("split", true),
            ("seed", true),
            ("max-late-rate", true),
            ("quick", false),
            ("csv", true),
            ("emit", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "train") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let kind = match args.str_opt("objective") {
        Some(name) => ObjectiveKind::parse(name)
            .with_context(|| format!("unknown objective '{name}' (expected energy or lifetime)"))?,
        None => ObjectiveKind::Energy,
    };
    let max_late_rate = args.f64_opt("max-late-rate")?;
    if let Some(r) = max_late_rate {
        if !(r.is_finite() && (0.0..=1.0).contains(&r)) {
            bail!("--max-late-rate must be a fraction in [0, 1] (got {r})");
        }
    }
    let trace_path = match args.str_opt("trace") {
        Some(path) => path.to_string(),
        None => match &config.workload.arrival {
            crate::config::schema::ArrivalSpec::Trace { path, .. } => path.clone(),
            _ => bail!(
                "no trace to train on: pass --trace <file> or use a config whose \
                 arrival_kind is 'trace'"
            ),
        },
    };
    let replay = requests::TraceReplay::from_file(&trace_path)
        .with_context(|| format!("loading gap trace {trace_path}"))?;
    let mut gaps = replay.shared_gaps();
    // --quick: fit on a bounded prefix so smoke runs stay fast
    if args.flag("quick") || crate::bench::quick_mode() {
        const QUICK_GAPS: usize = 256;
        if gaps.len() > QUICK_GAPS {
            gaps = gaps[..QUICK_GAPS].to_vec().into();
        }
    }
    let tc = TrainConfig {
        budget: args.u64_opt("budget")?.unwrap_or(TrainConfig::DEFAULT_BUDGET as u64) as usize,
        split: args.f64_opt("split")?.unwrap_or(TrainConfig::DEFAULT_SPLIT),
        seed: args.u64_opt("seed")?.unwrap_or(0),
        objective: Objective {
            kind,
            max_late_rate,
        },
    };
    let runner = sweep_runner(&args)?;
    println!(
        "training bandit on {trace_path} ({} gaps): objective {}, {} candidate alphas",
        gaps.len(),
        tc.objective.label(),
        tc.budget
    );
    let outcome = tuner::train(&config, &tc, &gaps, &runner)
        .with_context(|| format!("training bandit on {trace_path}"))?;
    print!("{}", outcome.render());
    println!(
        "apply: {}",
        tuner::flags_line(PolicySpec::BanditPolicy, &outcome.best)
    );
    if let Some(path) = args.str_opt("emit") {
        std::fs::write(path, tuner::yaml_fragment(PolicySpec::BanditPolicy, &outcome.best))
            .with_context(|| format!("writing trained params {path}"))?;
        println!("wrote {path}");
    }
    maybe_write_csv(&args, outcome.to_csv())
}

fn cmd_validate(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[("period", true), ("config", true), ("threads", true), ("help", false)],
    )?;
    if help_and_done(&args, "validate") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let period = args.f64_opt("period")?.unwrap_or(40.0);
    print!(
        "{}",
        validation::run_threaded(&config, period, &sweep_runner(&args)?).render()
    );
    Ok(())
}

fn cmd_ablate(argv: &[String]) -> Result<()> {
    use crate::experiments::ablation;

    let args = Args::parse(
        argv,
        &[
            ("requests", true),
            ("seed", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "ablate") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let requests = args.u64_opt("requests")?.unwrap_or(5_000);
    let seed = args.u64_opt("seed")?.unwrap_or(7);
    let runner = sweep_runner(&args)?;
    print!("{}", ablation::flash_floor_threaded(&config, &runner).render());
    print!(
        "{}",
        ablation::transient_sensitivity_threaded(&config, &runner).render()
    );
    print!(
        "{}",
        ablation::multi_accel_threaded(&config, requests, seed, &runner).render()
    );
    Ok(())
}

fn cmd_multi(argv: &[String]) -> Result<()> {
    use crate::coordinator::multi_sim::{run as run_multi, MultiSimConfig, SlotPolicy};
    use crate::coordinator::scheduler::Policy;
    use crate::runner::grid::cross;
    use crate::util::table::{fnum, Table};

    let args = Args::parse(
        argv,
        &[
            ("requests", true),
            ("burst", true),
            ("seed", true),
            ("gap-policy", true),
            ("slot-a-params", true),
            ("slot-b-params", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "multi") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let requests = args.u64_opt("requests")?.unwrap_or(2_000);
    let burst = args.u64_opt("burst")?.unwrap_or(4);
    let seed = args.u64_opt("seed")?.unwrap_or(17);
    let gap_policy = match args.str_opt("gap-policy") {
        Some(name) => PolicySpec::parse(name)
            .with_context(|| format!("unknown gap policy '{name}'"))?,
        None => PolicySpec::IdleWaitingM12,
    };
    // per-accelerator tuned params (`repro tune --emit` fragments): a
    // tuned heterogeneous fleet end-to-end
    let slot_fragment = |flag: &str| -> Result<Option<SlotPolicy>> {
        match args.str_opt(flag) {
            None => Ok(None),
            Some(path) => {
                let (spec, params) = crate::tuner::load_fragment(path)?;
                Ok(Some(SlotPolicy { spec, params }))
            }
        }
    };
    let slot_a = slot_fragment("slot-a-params")?;
    let slot_b = slot_fragment("slot-b-params")?;
    let slot_policies: Vec<Option<SlotPolicy>> = if slot_a.is_some() || slot_b.is_some() {
        vec![slot_a, slot_b]
    } else {
        Vec::new()
    };
    for (label, sp) in [("A", slot_policies.first()), ("B", slot_policies.get(1))] {
        if let Some(Some(sp)) = sp {
            println!(
                "slot {label}: {} ({})",
                sp.spec.name(),
                crate::tuner::params_label(sp.spec, &sp.params)
            );
        }
    }
    let runner = sweep_runner(&args)?;

    // mix × policy as one grid: the heavy event-driven runs parallelize,
    // the table order stays row-major deterministic.
    let grid = cross(
        &[0.0, 0.1, 0.25, 0.5],
        &[
            ("fifo", Policy::Fifo),
            ("batch-8", Policy::BatchBySlot { window: 8 }),
        ],
    );
    let rows = runner.run(&grid, |cell| {
        let (mix, (label, policy)) = *cell.params;
        let report = run_multi(
            &config,
            &MultiSimConfig {
                mix,
                requests,
                burst,
                policy,
                gap_policy,
                slot_policies: slot_policies.clone(),
                seed,
            },
        );
        (mix, label, report)
    });

    let mut t = Table::new(&[
        "mix",
        "policy",
        "reconfigs",
        "reordered",
        "energy (J)",
        "mean lat (ms)",
        "late (%)",
    ])
    .with_title(format!(
        "event-driven multi-accelerator sim: {requests} requests, burst {burst}"
    ));
    for (mix, label, report) in rows {
        t.row(&[
            fnum(mix, 2),
            label.into(),
            report.reconfigurations.to_string(),
            report.reordered.to_string(),
            fnum(report.energy.joules(), 3),
            fnum(report.mean_latency.millis(), 2),
            fnum(report.p_late * 100.0, 1),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// The `--sources >= 2` branch of `repro serve`: the event-driven
/// multi-client coordinator on the shared energy ledger. Artifact-free —
/// it exercises scheduling/admission/gap-policy accounting, not PJRT.
#[allow(clippy::too_many_arguments)]
fn serve_multi_cli(
    args: &Args,
    config: &SimConfig,
    kind: PolicySpec,
    params: PolicyParams,
    period: Duration,
    sources: usize,
    max_requests: u64,
    seed: u64,
) -> Result<()> {
    use crate::coordinator::scheduler::Policy as SchedPolicy;
    use crate::coordinator::serving::{poisson_sources, serve_multi, MultiServeOptions};

    // in multi mode --window is the scheduler's batching window; it rides
    // the same flag as the quantile-policy window and shares its >= 1
    // validation (policy_params_from_args already rejected 0)
    let window = match args.u64_opt("window")? {
        Some(w) => w as usize,
        None => config.serve.window,
    };
    let max_queue = match args.u64_opt("max-queue")? {
        Some(0) => bail!("--max-queue must be at least 1"),
        Some(n) => n as usize,
        None => config.serve.max_queue,
    };
    // offered load is conserved: n sources at mean gap n·period present
    // the same aggregate rate as one client at `period`
    let mean_gap = Duration::from_millis(period.millis() * sources as f64);
    let slack = match args.f64_opt("deadline-slack-ms")? {
        Some(ms) => {
            if !(ms.is_finite() && ms > 0.0) {
                bail!("--deadline-slack-ms must be a positive number of milliseconds (got {ms})");
            }
            Duration::from_millis(ms)
        }
        None => config.serve.deadline_slack.unwrap_or(mean_gap),
    };
    let per_source = ((max_requests as usize) / sources).max(1);
    let streams = poisson_sources(sources, per_source, mean_gap, slack, seed);
    let opts = MultiServeOptions {
        sched: SchedPolicy::BatchBySlot { window },
        max_queue,
        gap_policy: kind,
        params,
    };
    println!(
        "multi-client serve: {sources} sources x {per_source} requests, window {window}, \
         max queue {max_queue}, gap policy {}",
        kind.name()
    );
    let report = serve_multi(config, &opts, &streams);
    print!("{}", report.metrics.render());
    println!(
        "served: {} | reconfigurations: {} | reordered: {} | budget exhausted: {}",
        report.served, report.reconfigurations, report.reordered, report.budget_exhausted
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            ("policy", true),
            ("strategy", true), // legacy alias for --policy
            ("period", true),
            ("requests", true),
            ("variant", true),
            ("arrival", true),
            ("trace", true),
            ("seed", true),
            ("sources", true),
            ("max-queue", true),
            ("deadline-slack-ms", true),
            ("keep-alive", false),
            ("quick", false),
            ("timeout-ms", true),
            ("ema-alpha", true),
            ("window", true),
            ("quantile", true),
            ("saving", true),
            ("components", true),
            ("table", true),
            ("params-file", true),
            ("config", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "serve") {
        return Ok(());
    }
    let config = load_config(&args)?;
    // --params-file: a tuned/trained fragment (`repro tune|train --emit`)
    // as the base point; explicit --policy and knob flags still override
    let fragment = match args.str_opt("params-file") {
        Some(path) => Some(crate::tuner::load_fragment(path)?),
        None => None,
    };
    let kind = match args.str_opt("policy").or_else(|| args.str_opt("strategy")) {
        Some(name) => PolicySpec::parse(name)
            .with_context(|| format!("unknown policy '{name}'"))?,
        None => fragment
            .as_ref()
            .map(|(spec, _)| *spec)
            .unwrap_or(config.workload.policy),
    };
    let base = fragment.map(|(_, p)| p).unwrap_or(config.workload.params);
    let params = policy_params_from_args(&args, base)?;
    let period_ms = args.f64_opt("period")?.unwrap_or(40.0);
    if !(period_ms.is_finite() && period_ms > 0.0) {
        bail!("--period must be a positive number of milliseconds (got {period_ms})");
    }
    let period = Duration::from_millis(period_ms);
    let quick = args.flag("quick") || crate::bench::quick_mode();
    let max_requests = args
        .u64_opt("requests")?
        .unwrap_or(if quick { 40 } else { 100 });
    if max_requests == 0 {
        bail!("--requests must be at least 1");
    }
    let seed = args.u64_opt("seed")?.unwrap_or(0);
    let sources = match args.u64_opt("sources")? {
        Some(0) => bail!("--sources must be at least 1"),
        Some(n) => n as usize,
        None => config.serve.sources,
    };
    if sources >= 2 {
        return serve_multi_cli(
            &args,
            &config,
            kind,
            params,
            period,
            sources,
            max_requests,
            seed,
        );
    }
    let variant = match args.str_opt("variant") {
        Some("int8") => Variant::ForecastInt8,
        Some("f32") | None => Variant::Forecast,
        Some(other) => bail!("unknown variant '{other}' (expected f32 or int8)"),
    };
    let mut arrivals: Box<dyn requests::ArrivalProcess> = if let Some(path) =
        args.str_opt("trace")
    {
        Box::new(
            requests::TraceReplay::from_file(path)
                .with_context(|| format!("loading arrival trace {path}"))?,
        )
    } else {
        match args.str_opt("arrival") {
            Some("poisson") => Box::new(requests::Poisson::new(
                period,
                Duration::from_millis(
                    crate::config::schema::ArrivalSpec::DEFAULT_POISSON_MIN_GAP_MS,
                ),
                seed,
            )),
            Some("periodic") => Box::new(requests::Periodic { period }),
            // no override: honour the config's arrival spec (periodic,
            // jittered, poisson or a trace file) via the shared builder
            None if args.str_opt("period").is_none() => {
                requests::build(&config.workload.arrival, seed)
                    .context("building arrival process from config")?
            }
            None => Box::new(requests::Periodic { period }),
            Some(other) => bail!("unknown arrival process '{other}'"),
        }
    };

    let runtime = crate::runtime::pool::default_runtime()
        .context("loading artifacts (run `make artifacts` first)")?;
    runtime.self_check().context("runtime self-check")?;

    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let mut policy = build_with(kind, &model, &params);
    let server_cfg = ServerConfig {
        sim: &config,
        variant,
        max_requests,
        keep_alive: args.flag("keep-alive"),
    };
    let report = serve(&server_cfg, &runtime, policy.as_mut(), arrivals.as_mut())?;
    print!("{}", report.metrics.render());
    println!(
        "configurations: {} | budget exhausted: {}",
        report.configurations, report.budget_exhausted
    );
    if let Some(last) = report.served.last() {
        println!(
            "last forecast: {:.6} (host latency {:.3} ms)",
            last.forecast,
            last.host_latency.millis()
        );
    }
    Ok(())
}

fn cmd_plan(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[("period", true), ("budget", true), ("config", true), ("help", false)],
    )?;
    if help_and_done(&args, "plan") {
        return Ok(());
    }
    let mut config = load_config(&args)?;
    if let Some(budget) = args.f64_opt("budget")? {
        config.workload.energy_budget = crate::util::units::Energy::from_joules(budget);
    }
    let period = Duration::from_millis(
        args.f64_opt("period")?
            .context("--period <ms> is required for plan")?,
    );
    let model = Analytical::new(&config.item, config.workload.energy_budget);

    println!("policy plan for T_req = {:.2} ms, budget = {:.0} J:", period.millis(), config.workload.energy_budget.joules());
    // The closed forms behind `predict` evaluate the advanced policies at
    // their default tunables (M1+2 idle mode, break-even τ) — warn rather
    // than silently describe a different deployment than the config's.
    if config.workload.params != PolicyParams::default() {
        println!(
            "note: this config sets policy_params, which the closed-form plan ignores \
             (predictions assume the default M1+2 idle mode and break-even timeout); \
             simulation commands (exp4, serve, multi) do honour them"
        );
    }
    let mut best: Option<(PolicySpec, u64)> = None;
    for kind in [
        PolicySpec::OnOff,
        PolicySpec::IdleWaiting,
        PolicySpec::IdleWaitingM1,
        PolicySpec::IdleWaitingM12,
        PolicySpec::Timeout,
        PolicySpec::RandomizedSkiRental,
        PolicySpec::WindowedQuantile,
    ] {
        let p = model.predict(kind, period);
        match p.n_max {
            Some(n) => {
                println!(
                    "  {:<18} {:>12} items, lifetime {:>8.2} h",
                    kind.name(),
                    crate::util::table::fcount(n),
                    p.lifetime.hours()
                );
                if best.map(|(_, bn)| n > bn).unwrap_or(true) {
                    best = Some((kind, n));
                }
            }
            None => println!("  {:<18} infeasible (period below item latency)", kind.name()),
        }
    }
    if let Some((kind, _)) = best {
        println!("recommendation: {}", kind.name());
    }
    for (label, k) in [
        ("baseline", PolicySpec::IdleWaiting),
        ("method 1", PolicySpec::IdleWaitingM1),
        ("method 1+2", PolicySpec::IdleWaitingM12),
    ] {
        let t = crossover::asymptotic(&model, model.item.idle_power(k));
        println!("crossover vs On-Off ({label}): {:.2} ms", t.millis());
    }
    Ok(())
}

/// `repro fleet`: the fleet-scale DES — a per-device survey over a shared
/// gap trace (sharded across the sweep runner, streaming aggregates only)
/// plus wake-placement routing of a shared arrival stream. `--trace` or
/// `--period` override the config's arrival spec; `--quick` shrinks the
/// run for smoke tests. Output is byte-identical at any `--threads N`.
fn cmd_fleet(argv: &[String]) -> Result<()> {
    use crate::coordinator::fleet::{run_fleet, FleetOptions, Placement};

    let args = Args::parse(
        argv,
        &[
            ("devices", true),
            ("steps", true),
            ("requests", true),
            ("placement", true),
            ("trace", true),
            ("period", true),
            ("seed", true),
            ("deadline-ms", true),
            ("fault-config-rate", true),
            ("retry-max", true),
            ("backoff-ms", true),
            ("quick", false),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "fleet") {
        return Ok(());
    }
    let mut config = load_config(&args)?;
    if let Some(n) = args.u64_opt("devices")? {
        if n == 0 {
            bail!("--devices must be at least 1");
        }
        config.fleet.devices = n as usize;
    }
    if let Some(seed) = args.u64_opt("seed")? {
        config.fleet.seed = seed;
    }
    if let Some(ms) = args.f64_opt("deadline-ms")? {
        if !(ms.is_finite() && ms > 0.0) {
            bail!("--deadline-ms must be a positive number of milliseconds (got {ms})");
        }
        config.fleet.deadline = Some(Duration::from_millis(ms));
    }
    // fault-injection overrides: a composite configuration fault rate
    // (split across the four scenarios exactly as `repro faults` splits
    // it) plus the retry policy knobs, written into the config's faults
    // block so every device derives its stream from it
    if let Some(rate) = args.f64_opt("fault-config-rate")? {
        if !(rate.is_finite() && (0.0..=1.0).contains(&rate)) {
            bail!("--fault-config-rate must be in [0, 1] (got {rate})");
        }
        config.faults = crate::experiments::faults::spec_for_rate(
            rate,
            config.faults.seed,
            config.faults.retry_max,
            config.faults.backoff,
        );
    }
    if let Some(n) = args.u64_opt("retry-max")? {
        if n == 0 {
            bail!("--retry-max must be at least 1");
        }
        config.faults.retry_max = n as u32;
    }
    if let Some(ms) = args.f64_opt("backoff-ms")? {
        if !(ms.is_finite() && ms >= 0.0) {
            bail!("--backoff-ms must be a non-negative number of milliseconds (got {ms})");
        }
        config.faults.backoff = Duration::from_millis(ms);
    }
    // arrival overrides: a gap-trace file beats --period beats the config
    if let Some(path) = args.str_opt("trace") {
        let replay = requests::TraceReplay::from_file(path)
            .with_context(|| format!("loading gap trace {path}"))?;
        let nominal = requests::trace_mean(&replay.shared_gaps());
        config.workload.arrival = crate::config::schema::ArrivalSpec::Trace {
            path: path.to_string(),
            nominal,
        };
    } else if let Some(ms) = args.f64_opt("period")? {
        if !(ms.is_finite() && ms > 0.0) {
            bail!("--period must be a positive number of milliseconds (got {ms})");
        }
        config.workload.arrival = crate::config::schema::ArrivalSpec::Periodic {
            period: Duration::from_millis(ms),
        };
    }
    let quick = args.flag("quick") || crate::bench::quick_mode();
    let defaults = if quick {
        FleetOptions {
            steps: 64,
            requests: 500,
            ..FleetOptions::default()
        }
    } else {
        FleetOptions::default()
    };
    let placement = match args.str_opt("placement") {
        Some(name) => Placement::parse(name).with_context(|| {
            format!(
                "unknown placement '{name}' (expected one of: {})",
                Placement::ALL.map(|p| p.name()).join(", ")
            )
        })?,
        None => defaults.placement,
    };
    let options = FleetOptions {
        steps: args
            .u64_opt("steps")?
            .map(|v| v as usize)
            .unwrap_or(defaults.steps),
        requests: args
            .u64_opt("requests")?
            .map(|v| v as usize)
            .unwrap_or(defaults.requests),
        placement,
    };
    let runner = sweep_runner(&args)?;
    let report = run_fleet(&config, &options, &runner).context("running the fleet simulation")?;
    print!("{}", report.render());
    maybe_write_csv(&args, report.to_csv())
}

/// `repro faults`: the robustness sweep — configuration fault rate ×
/// gap policy under the deterministic fault injector, answering at what
/// failure rate Idle-Waiting's energy advantage over On-Off widens
/// beyond its fault-free baseline. `--quick` shrinks the run for smoke
/// tests; output is byte-identical at any `--threads N`.
fn cmd_faults(argv: &[String]) -> Result<()> {
    use crate::experiments::faults::{self, FaultsConfig};

    let args = Args::parse(
        argv,
        &[
            ("items", true),
            ("period", true),
            ("seed", true),
            ("retry-max", true),
            ("backoff-ms", true),
            ("quick", false),
            ("csv", true),
            ("config", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "faults") {
        return Ok(());
    }
    let config = load_config(&args)?;
    let defaults = FaultsConfig::default();
    let quick = args.flag("quick") || crate::bench::quick_mode();
    let items = args
        .u64_opt("items")?
        .unwrap_or(if quick { 300 } else { defaults.items });
    if items == 0 {
        bail!("--items must be at least 1");
    }
    let period_ms = args
        .f64_opt("period")?
        .unwrap_or_else(|| config.workload.arrival.mean_period().millis());
    if !(period_ms.is_finite() && period_ms > 0.0) {
        bail!("--period must be a positive number of milliseconds (got {period_ms})");
    }
    let retry_max = match args.u64_opt("retry-max")? {
        Some(0) => bail!("--retry-max must be at least 1"),
        Some(n) => n as u32,
        None => defaults.retry_max,
    };
    let backoff_ms = args.f64_opt("backoff-ms")?.unwrap_or(defaults.backoff_ms);
    if !(backoff_ms.is_finite() && backoff_ms >= 0.0) {
        bail!("--backoff-ms must be a non-negative number of milliseconds (got {backoff_ms})");
    }
    let fc = FaultsConfig {
        items,
        period_ms,
        seed: args.u64_opt("seed")?.unwrap_or(defaults.seed),
        retry_max,
        backoff_ms,
    };
    let result = faults::run_threaded(&config, &fc, &sweep_runner(&args)?);
    print!("{}", result.render());
    maybe_write_csv(&args, result.to_csv())
}

/// Every target `repro bench` can register, in registration order — the
/// vocabulary `--filter` matches against, listed verbatim when a filter
/// matches nothing.
const BENCH_TARGETS: [&str; 13] = [
    "des_idle_waiting_items",
    "des_onoff_items",
    "des_idle_waiting_scalar_items",
    "des_onoff_scalar_items",
    "des_onoff_golden_items",
    "event_queue_events",
    "fleet_step_devices",
    "fleet_route_requests",
    "serve_queue_requests",
    "sweep_exp2_cells",
    "sweep_exp4_cells",
    "tune_halving_evals",
    "learned_policy_plan_gaps",
];

/// `repro bench`: time the hot paths in-process and (optionally) write
/// the results as machine-readable JSON, schema
/// `[{name, iters, ns_per_iter, throughput}]` — so the perf trajectory
/// lands in version-controllable `BENCH_*.json` files instead of
/// terminal scrollback. `throughput` is work units per second with the
/// unit named by the benchmark (simulated items, queue events, sweep
/// cells, tuner DES evaluations).
fn cmd_bench(argv: &[String]) -> Result<()> {
    use crate::bench::{black_box, targets, Bench};
    use crate::coordinator::tracegen::{self, TraceKind};
    use crate::experiments::{exp2, exp4_policies};
    use crate::tuner::{self, SearchStrategy, TuneConfig};

    let args = Args::parse(
        argv,
        &[
            ("json", true),
            ("quick", false),
            ("filter", true),
            ("items", true),
            ("threads", true),
            ("help", false),
        ],
    )?;
    if help_and_done(&args, "bench") {
        return Ok(());
    }
    let config = paper_default();
    let quick = args.flag("quick") || crate::bench::quick_mode();
    let items = args.u64_opt("items")?.unwrap_or(if quick { 500 } else { 10_000 });
    if items == 0 {
        bail!("--items must be at least 1");
    }
    let runner = sweep_runner(&args)?;
    let filter = args.str_opt("filter");
    let want = |name: &str| filter.map(|f| name.contains(f)).unwrap_or(true);
    let mut bench = Bench::new(format!("repro bench ({} items/DES run)", items));
    if quick {
        bench = bench.quick();
    }

    // --- the DES hot loop (shared bodies with benches/hotpath.rs, so
    // the two harnesses stay comparable by construction) ---
    if want("des_idle_waiting_items") {
        targets::des_idle_waiting(&mut bench, "des_idle_waiting_items", &config, items);
    }
    if want("des_onoff_items") {
        targets::des_onoff(&mut bench, "des_onoff_items", &config, items);
    }
    if want("des_idle_waiting_scalar_items") {
        targets::des_idle_waiting_scalar(
            &mut bench,
            "des_idle_waiting_scalar_items",
            &config,
            items,
        );
    }
    if want("des_onoff_scalar_items") {
        targets::des_onoff_scalar(&mut bench, "des_onoff_scalar_items", &config, items);
    }
    if want("des_onoff_golden_items") {
        targets::des_onoff_golden(&mut bench, "des_onoff_golden_items", &config, items);
    }
    if want("event_queue_events") {
        targets::event_queue(&mut bench, "event_queue_events");
    }

    // --- the fleet DES (survey sharding + placement routing) ---
    if want("fleet_step_devices") {
        targets::fleet_step_devices(&mut bench, "fleet_step_devices", &config, quick);
    }
    if want("fleet_route_requests") {
        targets::fleet_route_requests(&mut bench, "fleet_route_requests", &config, quick);
    }

    // --- the multi-client serving coordinator (queue + ledger on one clock) ---
    if want("serve_queue_requests") {
        targets::serve_queue_requests(&mut bench, "serve_queue_requests", &config, quick);
    }

    // --- the sweep engine (the benches/sweep.rs gate targets) ---
    if want("sweep_exp2_cells") {
        let step = if quick { 0.5 } else { 0.05 };
        let cells = exp2::run_threaded(&config, step, &runner).samples.len();
        bench.bench_units("sweep_exp2_cells", cells as f64, || {
            black_box(exp2::run_threaded(&config, step, &runner).samples.len());
        });
    }
    if want("sweep_exp4_cells") {
        let e4 = exp4_policies::Exp4Config {
            items: if quick { 100 } else { 300 },
            period_ms: 40.0,
            seed: 7,
        };
        let cells = exp4_policies::run_threaded(&config, &e4, &runner)
            .context("exp4 bench cell")?
            .rows
            .len();
        bench.bench_units("sweep_exp4_cells", cells as f64, || {
            black_box(
                exp4_policies::run_threaded(&config, &e4, &runner)
                    .expect("exp4 bench sweep")
                    .rows
                    .len(),
            );
        });
    }

    // --- the tuner (halving rungs resume prefixes; dedupe; Arc trace) ---
    if want("tune_halving_evals") {
        let gaps: std::sync::Arc<[Duration]> =
            tracegen::generate_durations(TraceKind::BurstyIot, 128, 40.0, 1).into();
        let tc = TuneConfig {
            search: SearchStrategy::Halving,
            budget: 16,
            seed: 5,
            ..TuneConfig::for_spec(PolicySpec::WindowedQuantile)
        };
        let evals = tuner::tune(&config, &tc, &gaps, &runner)
            .context("tuner bench run")?
            .trajectory
            .iter()
            .filter(|p| p.metrics.is_some())
            .count();
        bench.bench_units("tune_halving_evals", evals as f64, || {
            black_box(
                tuner::tune(&config, &tc, &gaps, &runner)
                    .expect("tuner bench run")
                    .best,
            );
        });
    }

    // --- the learned policies' batched planning hot path ---
    if want("learned_policy_plan_gaps") {
        targets::learned_policy_plan_gaps(&mut bench, "learned_policy_plan_gaps", &config, items);
    }

    if bench.results().is_empty() {
        bail!(
            "--filter '{}' matched no benchmark; valid targets:\n  {}",
            filter.unwrap_or_default(),
            BENCH_TARGETS.join("\n  ")
        );
    }
    print!("{}", bench.render());
    if let Some(path) = args.str_opt("json") {
        let mut body = bench.to_json().render_pretty();
        body.push('\n');
        std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// One recorded `repro bench --json` row: the comparison key plus the
/// per-iteration cost the regression gate is applied to.
struct RecordedBench {
    name: String,
    ns_per_iter: f64,
}

/// Parse a `repro bench --json` recording
/// (`[{name, iters, ns_per_iter, throughput}]`) into comparison rows.
fn load_bench_rows(path: &str) -> Result<Vec<RecordedBench>> {
    use crate::util::json::Json;
    let body = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let json = Json::parse(&body).with_context(|| format!("parsing {path}"))?;
    let rows = json
        .as_arr()
        .with_context(|| format!("{path}: expected a JSON array of bench results"))?;
    rows.iter()
        .map(|row| {
            let name = row
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("{path}: result row without a string 'name'"))?;
            let ns_per_iter = row
                .get("ns_per_iter")
                .and_then(Json::as_f64)
                .with_context(|| format!("{path}: '{name}' lacks a numeric 'ns_per_iter'"))?;
            Ok(RecordedBench {
                name: name.to_string(),
                ns_per_iter,
            })
        })
        .collect()
}

/// `repro bench-compare <before.json> <after.json>`: diff two recorded
/// bench runs into a markdown before/after table with per-target speedup
/// ratios, and exit non-zero when any target shared by both recordings
/// slowed down by more than `--max-regress` (default 25%). Targets
/// present in only one file are listed but never gate. The `--out`
/// report is written before the gate fires, so CI can upload it for a
/// failing run too.
fn cmd_bench_compare(argv: &[String]) -> Result<()> {
    // two leading positionals, then ordinary --key value options
    let mut positionals: Vec<String> = Vec::new();
    let mut options: Vec<String> = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let token = &argv[i];
        if let Some(name) = token.strip_prefix("--") {
            options.push(token.clone());
            let takes_value = ["out", "max-regress"].contains(&name) && !name.contains('=');
            if takes_value {
                i += 1;
                if let Some(value) = argv.get(i) {
                    options.push(value.clone());
                }
            }
        } else {
            positionals.push(token.clone());
        }
        i += 1;
    }
    let args = Args::parse(&options, &[("out", true), ("max-regress", true), ("help", false)])?;
    if help_and_done(&args, "bench-compare") {
        return Ok(());
    }
    let [before_path, after_path] = positionals.as_slice() else {
        bail!(
            "bench-compare takes exactly two positional arguments: \
             <before.json> <after.json> (got {})",
            positionals.len()
        );
    };
    let max_regress = args.f64_opt("max-regress")?.unwrap_or(0.25);
    if !(max_regress.is_finite() && max_regress >= 0.0) {
        bail!("--max-regress must be a non-negative fraction (got {max_regress})");
    }
    let before = load_bench_rows(before_path)?;
    let after = load_bench_rows(after_path)?;
    let lookup_after = |name: &str| {
        after
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.ns_per_iter)
    };

    let mut lines = vec![
        format!("# bench-compare: {before_path} \u{2192} {after_path}"),
        String::new(),
        "| target | before ns/iter | after ns/iter | speedup | verdict |".to_string(),
        "|---|---:|---:|---:|---|".to_string(),
    ];
    let mut shared = 0usize;
    let mut regressed: Vec<&str> = Vec::new();
    for row in &before {
        let Some(after_ns) = lookup_after(&row.name) else {
            lines.push(format!(
                "| {} | {:.1} | \u{2014} | \u{2014} | removed (ungated) |",
                row.name, row.ns_per_iter
            ));
            continue;
        };
        shared += 1;
        let speedup = row.ns_per_iter / after_ns;
        let verdict = if after_ns / row.ns_per_iter - 1.0 > max_regress {
            regressed.push(&row.name);
            "**REGRESS**"
        } else if speedup >= 1.0 {
            "ok"
        } else {
            "ok (within gate)"
        };
        lines.push(format!(
            "| {} | {:.1} | {:.1} | {:.2}\u{d7} | {verdict} |",
            row.name, row.ns_per_iter, after_ns, speedup
        ));
    }
    for row in &after {
        if !before.iter().any(|b| b.name == row.name) {
            lines.push(format!(
                "| {} | \u{2014} | {:.1} | \u{2014} | new (ungated) |",
                row.name, row.ns_per_iter
            ));
        }
    }
    lines.push(String::new());
    lines.push(if regressed.is_empty() {
        format!(
            "verdict: PASS \u{2014} {shared} shared target(s), none slower than \
             {:.0}% over baseline",
            max_regress * 100.0
        )
    } else {
        format!(
            "verdict: FAIL \u{2014} {} of {shared} shared target(s) regressed beyond \
             {:.0}%: {}",
            regressed.len(),
            max_regress * 100.0,
            regressed.join(", ")
        )
    });
    lines.push(String::new());
    let report = lines.join("\n");

    print!("{report}");
    if let Some(path) = args.str_opt("out") {
        std::fs::write(path, &report).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if shared == 0 {
        bail!("{before_path} and {after_path} share no benchmark names \u{2014} nothing to gate");
    }
    if !regressed.is_empty() {
        bail!(
            "{} benchmark target(s) regressed beyond {:.0}%: {}",
            regressed.len(),
            max_regress * 100.0,
            regressed.join(", ")
        );
    }
    Ok(())
}

fn cmd_all(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[("step", true), ("threads", true), ("help", false)])?;
    if help_and_done(&args, "all") {
        return Ok(());
    }
    let step = step_arg(&args, 0.01)?;
    let runner = sweep_runner(&args)?;
    let config = paper_default();
    println!("=== Fig 2 ===");
    print!("{}", fig2::run().render());
    println!("\n=== Experiment 1 (Fig 7) ===");
    let e1 = exp1::run_threaded(FpgaModel::Xc7s15, &runner);
    print!("{}", e1.render_summary());
    let e1b = exp1::run_threaded(FpgaModel::Xc7s25, &runner);
    print!("{}", e1b.render_summary());
    println!("\n=== Experiment 2 (Figs 8-9) ===");
    let e2 = exp2::run_threaded(&config, step, &runner);
    print!("{}", e2.render_figs());
    print!("{}", e2.render_summary(&config));
    println!("\n=== Experiment 3 (Table 3, Figs 10-11) ===");
    let e3 = exp3::run_threaded(&config, step, &runner);
    print!("{}", e3.render_table3());
    print!("{}", e3.render_figs());
    print!("{}", e3.render_summary());
    println!("\n=== Validation (\u{a7}5.3) ===");
    print!("{}", validation::run_threaded(&config, 40.0, &runner).render());
    println!("\n=== Experiment 4 (online policies \u{d7} irregular arrivals) ===");
    print!(
        "{}",
        crate::experiments::exp4_policies::run_threaded(
            &config,
            &crate::experiments::exp4_policies::Exp4Config::default(),
            &runner,
        )
        .context("exp4 arrival trace")?
        .render()
    );
    println!("\n=== Experiment 5 (multi-client scheduling \u{d7} offered load) ===");
    print!(
        "{}",
        crate::experiments::exp5_serving::run_threaded(
            &config,
            &crate::experiments::exp5_serving::Exp5Config::default(),
            &runner,
        )
        .render()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_args_prints_usage() {
        run(&[]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn fig2_runs() {
        run(&sv(&["fig2"])).unwrap();
    }

    #[test]
    fn exp1_runs_with_model() {
        run(&sv(&["exp1", "--model", "XC7S25"])).unwrap();
    }

    #[test]
    fn exp2_coarse_runs() {
        run(&sv(&["exp2", "--step", "5"])).unwrap();
    }

    #[test]
    fn exp2_threaded_runs() {
        run(&sv(&["exp2", "--step", "5", "--threads", "2"])).unwrap();
    }

    #[test]
    fn exp3_coarse_runs() {
        run(&sv(&["exp3", "--step", "5"])).unwrap();
    }

    #[test]
    fn exp4_small_grid_runs() {
        run(&sv(&["exp4", "--items", "50", "--threads", "2"])).unwrap();
    }

    #[test]
    fn exp5_small_grid_runs() {
        run(&sv(&["exp5", "--requests", "40", "--threads", "2"])).unwrap();
    }

    #[test]
    fn exp5_rejects_bad_inputs() {
        assert!(run(&sv(&["exp5", "--requests", "0"])).is_err());
        assert!(run(&sv(&["exp5", "--sources", "0"])).is_err());
        assert!(run(&sv(&["exp5", "--period", "-4"])).is_err());
    }

    #[test]
    fn serve_multi_source_runs_without_artifacts() {
        // the >= 2 sources branch exercises the coordinator on the
        // simulated ledger only — no PJRT artifacts involved
        run(&sv(&["serve", "--sources", "2", "--requests", "24", "--quick"])).unwrap();
    }

    #[test]
    fn serve_multi_rejects_bad_inputs() {
        assert!(run(&sv(&["serve", "--sources", "0"])).is_err());
        assert!(run(&sv(&["serve", "--sources", "2", "--max-queue", "0"])).is_err());
        assert!(run(&sv(&["serve", "--sources", "2", "--deadline-slack-ms", "-1"])).is_err());
        assert!(run(&sv(&["serve", "--sources", "2", "--window", "0"])).is_err());
        assert!(run(&sv(&["serve", "--sources", "2", "--period", "-4"])).is_err());
        assert!(run(&sv(&["serve", "--requests", "0"])).is_err());
    }

    #[test]
    fn gen_trace_prints_to_stdout() {
        run(&sv(&["gen-trace", "--kind", "mmpp", "--gaps", "16"])).unwrap();
    }

    #[test]
    fn gen_trace_rejects_bad_inputs() {
        assert!(run(&sv(&["gen-trace", "--kind", "warp"])).is_err());
        assert!(run(&sv(&["gen-trace", "--gaps", "0"])).is_err());
        assert!(run(&sv(&["gen-trace", "--period", "-4"])).is_err());
    }

    #[test]
    fn serve_rejects_out_of_range_tunables() {
        // tunable validation fires before the artifact lookup, so these
        // fail with the params message whether or not artifacts exist
        for argv in [
            vec!["serve", "--policy", "quantile", "--quantile", "1.5"],
            vec!["serve", "--policy", "quantile", "--window", "0"],
            vec!["serve", "--policy", "timeout", "--timeout-ms", "-1"],
            vec!["serve", "--policy", "ema", "--ema-alpha", "7"],
            vec!["serve", "--saving", "turbo"],
            vec!["serve", "--policy", "bayes", "--components", "9"],
            vec!["serve", "--policy", "bandit", "--table", "iii"],
        ] {
            assert!(run(&sv(&argv)).is_err(), "{argv:?}");
        }
    }

    #[test]
    fn serve_accepts_a_trained_params_file() {
        let dir = std::env::temp_dir().join("idlewait_serve_params_file");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trained.yaml");
        let mut table = crate::config::schema::PolicyTable::hedge();
        table.0[0] = b'i';
        let params = PolicyParams {
            ema_alpha: 0.25,
            table: Some(table),
            ..PolicyParams::default()
        };
        std::fs::write(
            &path,
            crate::tuner::yaml_fragment(PolicySpec::BanditPolicy, &params),
        )
        .unwrap();
        // the fragment supplies both the policy and its params; the multi
        // source branch needs no PJRT artifacts
        run(&sv(&[
            "serve",
            "--sources",
            "2",
            "--requests",
            "24",
            "--quick",
            "--params-file",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(run(&sv(&["serve", "--sources", "2", "--params-file", "/no/such.yaml"])).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_quick_runs_and_emits_a_loadable_fragment() {
        let dir = std::env::temp_dir().join("idlewait_cmd_train");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.csv");
        crate::coordinator::tracegen::write_file(
            trace.to_str().unwrap(),
            crate::coordinator::tracegen::TraceKind::BurstyIot,
            96,
            40.0,
            1,
        )
        .unwrap();
        let emit = dir.join("trained.yaml");
        run(&sv(&[
            "train",
            "--trace",
            trace.to_str().unwrap(),
            "--budget",
            "4",
            "--quick",
            "--emit",
            emit.to_str().unwrap(),
        ]))
        .unwrap();
        let (spec, params) = crate::tuner::load_fragment(&emit).unwrap();
        assert_eq!(spec, PolicySpec::BanditPolicy);
        assert!(params.table.is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_rejects_bad_inputs() {
        // default config has a periodic arrival: no trace to train on
        assert!(run(&sv(&["train"])).is_err());
        assert!(run(&sv(&["train", "--trace", "/no/such/trace.csv"])).is_err());
    }

    #[test]
    fn fig2_series_runs() {
        run(&sv(&["fig2", "--series", "--threads", "2"])).unwrap();
    }

    #[test]
    fn plan_runs() {
        run(&sv(&["plan", "--period", "75"])).unwrap();
    }

    #[test]
    fn plan_requires_period() {
        assert!(run(&sv(&["plan"])).is_err());
    }

    #[test]
    fn helps_run() {
        for cmd in [
            "fig2",
            "exp1",
            "exp2",
            "exp3",
            "exp4",
            "exp5",
            "gen-trace",
            "tune",
            "train",
            "validate",
            "ablate",
            "multi",
            "serve",
            "plan",
            "fleet",
            "faults",
            "bench",
            "bench-compare",
            "all",
        ] {
            run(&sv(&[cmd, "--help"])).unwrap();
        }
    }

    #[test]
    fn fleet_small_runs() {
        run(&sv(&[
            "fleet",
            "--devices",
            "8",
            "--steps",
            "16",
            "--requests",
            "32",
            "--placement",
            "prefer-configured",
            "--threads",
            "2",
        ]))
        .unwrap();
    }

    #[test]
    fn fleet_rejects_bad_inputs() {
        assert!(run(&sv(&["fleet", "--devices", "0"])).is_err());
        assert!(run(&sv(&["fleet", "--placement", "warp"])).is_err());
        assert!(run(&sv(&["fleet", "--period", "-4"])).is_err());
        assert!(run(&sv(&["fleet", "--deadline-ms", "0"])).is_err());
        assert!(run(&sv(&["fleet", "--trace", "/no/such/trace.csv"])).is_err());
        assert!(run(&sv(&["fleet", "--fault-config-rate", "2"])).is_err());
        assert!(run(&sv(&["fleet", "--retry-max", "0"])).is_err());
        assert!(run(&sv(&["fleet", "--backoff-ms", "-1"])).is_err());
    }

    #[test]
    fn fleet_faulty_small_runs() {
        run(&sv(&[
            "fleet",
            "--devices",
            "6",
            "--steps",
            "8",
            "--requests",
            "24",
            "--fault-config-rate",
            "0.3",
            "--retry-max",
            "2",
            "--threads",
            "2",
        ]))
        .unwrap();
    }

    #[test]
    fn faults_small_runs_and_writes_csv() {
        let dir = std::env::temp_dir().join("idlewait_faults_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("faults.csv");
        run(&sv(&[
            "faults",
            "--items",
            "120",
            "--threads",
            "2",
            "--csv",
            path.to_str().unwrap(),
        ]))
        .unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("rate,policy,items,energy_mj"), "{body}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn faults_rejects_bad_inputs() {
        assert!(run(&sv(&["faults", "--items", "0"])).is_err());
        assert!(run(&sv(&["faults", "--period", "-4"])).is_err());
        assert!(run(&sv(&["faults", "--retry-max", "0"])).is_err());
        assert!(run(&sv(&["faults", "--backoff-ms", "-1"])).is_err());
    }

    #[test]
    fn bench_quick_writes_the_json_schema() {
        let dir = std::env::temp_dir().join("idlewait_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let path_str = path.to_str().unwrap();
        run(&sv(&[
            "bench",
            "--quick",
            "--filter",
            "event_queue",
            "--json",
            path_str,
        ]))
        .unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let json = crate::util::json::Json::parse(&body).unwrap();
        let rows = json.as_arr().expect("array of results");
        assert_eq!(rows.len(), 1);
        for key in ["name", "iters", "ns_per_iter", "throughput"] {
            assert!(rows[0].get(key).is_some(), "missing {key}");
        }
        assert_eq!(
            rows[0].get("name").and_then(crate::util::json::Json::as_str),
            Some("event_queue_events")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_rejects_an_unmatched_filter_and_zero_items() {
        let err = run(&sv(&["bench", "--quick", "--filter", "no-such-bench"])).unwrap_err();
        // the zero-match error enumerates the valid target vocabulary
        for name in BENCH_TARGETS {
            assert!(err.to_string().contains(name), "missing {name}: {err}");
        }
        assert!(run(&sv(&["bench", "--items", "0"])).is_err());
    }

    #[test]
    fn bench_compare_gates_regressions_and_reports_speedups() {
        let dir = std::env::temp_dir().join("idlewait_bench_compare");
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, body: &str| {
            let path = dir.join(name);
            std::fs::write(&path, body).unwrap();
            path.to_str().unwrap().to_string()
        };
        let before = write(
            "before.json",
            r#"[{"name":"a","iters":3,"ns_per_iter":1000.0,"throughput":1.0},
                {"name":"b","iters":3,"ns_per_iter":500.0,"throughput":2.0},
                {"name":"gone","iters":3,"ns_per_iter":9.0,"throughput":1.0}]"#,
        );
        // a 2.5x faster, b 4% slower (inside the default 25% gate),
        // "gone" removed and "fresh" added (both ungated)
        let faster = write(
            "faster.json",
            r#"[{"name":"a","iters":3,"ns_per_iter":400.0,"throughput":2.5},
                {"name":"b","iters":3,"ns_per_iter":520.0,"throughput":1.9},
                {"name":"fresh","iters":3,"ns_per_iter":7.0,"throughput":1.0}]"#,
        );
        run(&sv(&["bench-compare", &before, &faster])).unwrap();
        // ...but a tighter gate catches b's 4% drift
        assert!(run(&sv(&["bench-compare", &before, &faster, "--max-regress", "0.01"])).is_err());

        // a 40% slower: fails the default gate, naming the target
        let slower = write(
            "slower.json",
            r#"[{"name":"a","iters":3,"ns_per_iter":1400.0,"throughput":0.7},
                {"name":"b","iters":3,"ns_per_iter":500.0,"throughput":2.0}]"#,
        );
        let err = run(&sv(&["bench-compare", &before, &slower])).unwrap_err();
        assert!(err.to_string().contains('a'), "{err}");
        // --out lands the markdown report even when the gate fires
        let out = dir.join("report.md");
        let out_str = out.to_str().unwrap();
        let _ = run(&sv(&["bench-compare", &before, &slower, "--out", out_str]));
        let report = std::fs::read_to_string(&out).unwrap();
        assert!(report.contains("| target | before ns/iter | after ns/iter | speedup | verdict |"));
        assert!(report.contains("REGRESS"), "{report}");
        assert!(report.contains("removed (ungated)"), "{report}");
        assert!(report.contains("verdict: FAIL"), "{report}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_compare_rejects_bad_invocations() {
        // wrong arity, missing files, and disjoint recordings all error
        assert!(run(&sv(&["bench-compare"])).is_err());
        assert!(run(&sv(&["bench-compare", "/no/such/a.json"])).is_err());
        assert!(run(&sv(&["bench-compare", "/no/such/a.json", "/no/such/b.json"])).is_err());
        let dir = std::env::temp_dir().join("idlewait_bench_compare_disjoint");
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("a.json");
        let b = dir.join("b.json");
        std::fs::write(&a, r#"[{"name":"x","iters":1,"ns_per_iter":1.0,"throughput":1.0}]"#)
            .unwrap();
        std::fs::write(&b, r#"[{"name":"y","iters":1,"ns_per_iter":1.0,"throughput":1.0}]"#)
            .unwrap();
        let err = run(&sv(&[
            "bench-compare",
            a.to_str().unwrap(),
            b.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("share no benchmark"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tune_help_and_bad_policy() {
        run(&sv(&["tune", "--help"])).unwrap();
        assert!(run(&sv(&["tune", "--policy", "warp-drive", "--trace", "x.csv"])).is_err());
    }
}
