//! Minimal argument parser (clap is not in the offline vendor set).
//!
//! Supports the shapes the `repro` CLI needs: a subcommand followed by
//! `--key value` / `--flag` options. Unknown options are errors, values
//! are typed on extraction, and every subcommand gets `--help` for free.

use std::collections::BTreeMap;

/// Why argument parsing (or typed extraction) failed.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum ArgError {
    /// An option not in the command's accepted set.
    #[error("unknown option '--{0}'")]
    Unknown(String),
    /// A value-taking option at the end of argv.
    #[error("option '--{0}' requires a value")]
    MissingValue(String),
    /// A value that failed typed parsing.
    #[error("option '--{name}': cannot parse '{value}' as {ty}")]
    BadValue {
        name: String,
        value: String,
        ty: &'static str,
    },
    /// A bare positional argument (the CLI is option-only).
    #[error("unexpected positional argument '{0}'")]
    UnexpectedPositional(String),
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Options this command accepts: (name, takes_value).
    accepted: Vec<(&'static str, bool)>,
}

impl Args {
    /// Parse `argv` (after the subcommand) against a declared option set.
    pub fn parse(
        argv: &[String],
        accepted: &[(&'static str, bool)],
    ) -> Result<Args, ArgError> {
        let mut args = Args {
            accepted: accepted.to_vec(),
            ..Default::default()
        };
        let mut i = 0;
        while i < argv.len() {
            let token = &argv[i];
            let Some(name) = token.strip_prefix("--") else {
                return Err(ArgError::UnexpectedPositional(token.clone()));
            };
            // allow --key=value
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let Some((_, takes_value)) = accepted.iter().find(|(n, _)| *n == name) else {
                return Err(ArgError::Unknown(name.to_string()));
            };
            if *takes_value {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| ArgError::MissingValue(name.to_string()))?
                    }
                };
                args.values.insert(name.to_string(), value);
            } else {
                args.flags.push(name.to_string());
            }
            i += 1;
        }
        Ok(args)
    }

    /// True when the boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// A string option's value, if present.
    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// A float option's value, if present (typed error on junk).
    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>, ArgError> {
        self.typed_opt(name, "number", |v| v.parse::<f64>().ok())
    }

    /// An integer option's value, if present (typed error on junk).
    pub fn u64_opt(&self, name: &str) -> Result<Option<u64>, ArgError> {
        self.typed_opt(name, "integer", |v| v.parse::<u64>().ok())
    }

    fn typed_opt<T>(
        &self,
        name: &str,
        ty: &'static str,
        parse: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>, ArgError> {
        match self.values.get(name) {
            None => Ok(None),
            Some(v) => parse(v).map(Some).ok_or_else(|| ArgError::BadValue {
                name: name.to_string(),
                value: v.clone(),
                ty,
            }),
        }
    }

    /// Render the accepted options as help text.
    pub fn help(&self) -> String {
        self.accepted
            .iter()
            .map(|(name, takes_value)| {
                if *takes_value {
                    format!("  --{name} <value>")
                } else {
                    format!("  --{name}")
                }
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    const ACCEPTED: &[(&str, bool)] = &[
        ("period", true),
        ("step", true),
        ("requests", true),
        ("verbose", false),
    ];

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(&sv(&["--period", "40", "--verbose"]), ACCEPTED).unwrap();
        assert_eq!(a.f64_opt("period").unwrap(), Some(40.0));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.f64_opt("step").unwrap(), None);
    }

    #[test]
    fn parses_key_equals_value() {
        let a = Args::parse(&sv(&["--period=89.21"]), ACCEPTED).unwrap();
        assert_eq!(a.f64_opt("period").unwrap(), Some(89.21));
    }

    #[test]
    fn rejects_unknown() {
        assert!(matches!(
            Args::parse(&sv(&["--bogus"]), ACCEPTED),
            Err(ArgError::Unknown(_))
        ));
    }

    #[test]
    fn rejects_missing_value() {
        assert!(matches!(
            Args::parse(&sv(&["--period"]), ACCEPTED),
            Err(ArgError::MissingValue(_))
        ));
    }

    #[test]
    fn rejects_bad_type() {
        let a = Args::parse(&sv(&["--requests", "many"]), ACCEPTED).unwrap();
        assert!(matches!(
            a.u64_opt("requests"),
            Err(ArgError::BadValue { .. })
        ));
    }

    #[test]
    fn rejects_positional() {
        assert!(matches!(
            Args::parse(&sv(&["oops"]), ACCEPTED),
            Err(ArgError::UnexpectedPositional(_))
        ));
    }

    #[test]
    fn help_lists_options() {
        let a = Args::parse(&[], ACCEPTED).unwrap();
        let h = a.help();
        assert!(h.contains("--period <value>"));
        assert!(h.contains("--verbose"));
        assert!(!h.contains("--verbose <value>"));
    }
}
