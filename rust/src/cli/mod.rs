//! The `repro` command-line interface.

pub mod args;
pub mod commands;

pub use commands::{run, USAGE};
