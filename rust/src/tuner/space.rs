//! The tunable search space: which [`PolicyParams`] knobs apply to each
//! [`PolicySpec`] variant, their ranges and scales, and deterministic
//! candidate generation (grid enumeration and seeded random sampling).
//!
//! A [`ParamSpace`] is a declarative description, not a sampler with
//! hidden state: grid enumeration is a pure function of the space, and
//! random sampling draws from a caller-supplied [`Xoshiro256ss`] stream,
//! so every search strategy built on top is byte-identical at any
//! `--threads N`.

use crate::config::schema::{PolicyParams, PolicySpec};
use crate::device::rails::PowerSaving;
use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// How a knob's `[lo, hi]` range is traversed: linearly, or
/// multiplicatively (equal ratios between grid levels). Timeouts and
/// window lengths span orders of magnitude, so they use [`Scale::Log`];
/// quantiles live on a bounded interval and use [`Scale::Linear`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Equal absolute steps between levels.
    Linear,
    /// Equal ratios between levels (`lo` must be positive).
    Log,
}

/// One tunable dimension of a [`ParamSpace`]: a named [`PolicyParams`]
/// field with its range, scale and grid resolution.
#[derive(Debug, Clone)]
pub struct Knob {
    /// The `PolicyParams` field this knob drives; one of
    /// [`Knob::TIMEOUT_MS`], [`Knob::EMA_ALPHA`], [`Knob::WINDOW`],
    /// [`Knob::QUANTILE`], [`Knob::COMPONENTS`].
    pub name: &'static str,
    /// Range traversal (see [`Scale`]).
    pub scale: Scale,
    /// Inclusive lower bound.
    pub lo: f64,
    /// Inclusive upper bound.
    pub hi: f64,
    /// Round sampled/grid values to the nearest integer (window lengths).
    pub integer: bool,
    /// Number of grid levels [`Knob::grid`] enumerates.
    pub grid_levels: usize,
}

impl Knob {
    /// Knob name for the explicit ski-rental timeout (ms).
    pub const TIMEOUT_MS: &'static str = "timeout_ms";
    /// Knob name for the EMA smoothing factor.
    pub const EMA_ALPHA: &'static str = "ema_alpha";
    /// Knob name for the windowed-quantile ring-buffer length.
    pub const WINDOW: &'static str = "window";
    /// Knob name for the windowed-quantile planning quantile.
    pub const QUANTILE: &'static str = "quantile";
    /// Knob name for the Bayes-mixture component count.
    pub const COMPONENTS: &'static str = "components";

    /// The knob value at normalized position `t ∈ [0, 1]`.
    fn value_at(&self, t: f64) -> f64 {
        let v = match self.scale {
            Scale::Linear => self.lo + (self.hi - self.lo) * t,
            Scale::Log => self.lo * (self.hi / self.lo).powf(t),
        };
        if self.integer {
            v.round()
        } else {
            v
        }
    }

    /// The grid levels of this knob, low to high. Integer knobs dedupe
    /// adjacent levels that round to the same value.
    pub fn grid(&self) -> Vec<f64> {
        let n = self.grid_levels.max(2);
        let mut out: Vec<f64> = (0..n)
            .map(|i| self.value_at(i as f64 / (n - 1) as f64))
            .collect();
        out.dedup();
        out
    }

    /// One scale-uniform draw from the knob's range.
    pub fn sample(&self, rng: &mut Xoshiro256ss) -> f64 {
        self.value_at(rng.next_f64())
    }

    /// Write a knob value into a parameter point.
    pub fn apply(&self, params: &mut PolicyParams, value: f64) {
        match self.name {
            Self::TIMEOUT_MS => params.timeout = Some(Duration::from_millis(value)),
            Self::EMA_ALPHA => params.ema_alpha = value,
            Self::WINDOW => params.window = value.round().max(1.0) as usize,
            Self::QUANTILE => params.quantile = value,
            Self::COMPONENTS => params.components = value.round().clamp(2.0, 4.0) as usize,
            other => unreachable!("unknown knob '{other}'"),
        }
    }
}

/// The searchable space for one policy: a categorical idle-mode axis
/// (`savings`; empty when the policy has a fixed level, like the named
/// Idle-Waiting variants) and zero or more continuous [`Knob`]s.
#[derive(Debug, Clone)]
pub struct ParamSpace {
    /// The policy this space describes.
    pub spec: PolicySpec,
    /// Idle power-saving levels to try (`saving` tunable); empty = the
    /// policy's level is fixed and not searched.
    pub savings: Vec<PowerSaving>,
    /// Continuous/integer knobs to search.
    pub knobs: Vec<Knob>,
}

/// All three idle power-saving levels (the `saving` axis).
fn all_savings() -> Vec<PowerSaving> {
    vec![PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12]
}

impl ParamSpace {
    /// The search space for a policy. Ranges bracket the paper's
    /// operating points: timeouts span 0.5 ms – 5 s around the 89.21 /
    /// 499.06 ms crossovers, EMA alphas cover sluggish (0.02) to
    /// track-newest (1.0), windows 2–256 gaps around the default 64, and
    /// quantiles 0.05–0.95 around the default 0.9.
    pub fn for_spec(spec: PolicySpec) -> ParamSpace {
        let knobs: Vec<Knob> = match spec {
            PolicySpec::OnOff
            | PolicySpec::IdleWaiting
            | PolicySpec::IdleWaitingM1
            | PolicySpec::IdleWaitingM12
            | PolicySpec::Oracle => Vec::new(),
            PolicySpec::Timeout | PolicySpec::RandomizedSkiRental => vec![Knob {
                name: Knob::TIMEOUT_MS,
                scale: Scale::Log,
                lo: 0.5,
                hi: 5_000.0,
                integer: false,
                grid_levels: 8,
            }],
            PolicySpec::EmaPredictor => vec![Knob {
                name: Knob::EMA_ALPHA,
                scale: Scale::Log,
                lo: 0.02,
                hi: 1.0,
                integer: false,
                grid_levels: 6,
            }],
            PolicySpec::WindowedQuantile => vec![
                Knob {
                    name: Knob::WINDOW,
                    scale: Scale::Log,
                    lo: 2.0,
                    hi: 256.0,
                    integer: true,
                    grid_levels: 6,
                },
                Knob {
                    name: Knob::QUANTILE,
                    scale: Scale::Linear,
                    lo: 0.05,
                    hi: 0.95,
                    integer: false,
                    grid_levels: 7,
                },
            ],
            PolicySpec::BayesMixture => vec![Knob {
                name: Knob::COMPONENTS,
                scale: Scale::Linear,
                lo: 2.0,
                hi: 4.0,
                integer: true,
                grid_levels: 3,
            }],
            // the bandit's action table is trained (`repro train`), not
            // searched; only its feature-EMA smoothing is a knob
            PolicySpec::BanditPolicy => vec![Knob {
                name: Knob::EMA_ALPHA,
                scale: Scale::Log,
                lo: 0.02,
                hi: 1.0,
                integer: false,
                grid_levels: 6,
            }],
        };
        let savings = match spec {
            // the named strategies carry their level in the spec itself
            PolicySpec::OnOff
            | PolicySpec::IdleWaiting
            | PolicySpec::IdleWaitingM1
            | PolicySpec::IdleWaitingM12 => Vec::new(),
            _ => all_savings(),
        };
        ParamSpace {
            spec,
            savings,
            knobs,
        }
    }

    /// Whether there is anything to search at all (the static policies
    /// have neither a saving axis nor knobs).
    pub fn is_tunable(&self) -> bool {
        !self.savings.is_empty() || !self.knobs.is_empty()
    }

    /// Full-factorial enumeration: every saving level × every grid level
    /// of every knob, overlaid on `base` (knobs outside this space keep
    /// their `base` values). Order is deterministic: savings outer,
    /// knobs in declaration order, levels low to high.
    pub fn grid_candidates(&self, base: &PolicyParams) -> Vec<PolicyParams> {
        let mut out: Vec<PolicyParams> = if self.savings.is_empty() {
            vec![*base]
        } else {
            self.savings
                .iter()
                .map(|&s| PolicyParams { saving: s, ..*base })
                .collect()
        };
        for knob in &self.knobs {
            let levels = knob.grid();
            let mut next = Vec::with_capacity(out.len() * levels.len());
            for p in &out {
                for &v in &levels {
                    let mut q = *p;
                    knob.apply(&mut q, v);
                    next.push(q);
                }
            }
            out = next;
        }
        out
    }

    /// One random point: a uniformly chosen saving level plus a
    /// scale-uniform draw per knob, overlaid on `base`.
    pub fn sample(&self, base: &PolicyParams, rng: &mut Xoshiro256ss) -> PolicyParams {
        let mut p = *base;
        if !self.savings.is_empty() {
            p.saving = *rng.choose(&self.savings);
        }
        for knob in &self.knobs {
            let v = knob.sample(rng);
            knob.apply(&mut p, v);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_policies_have_nothing_to_tune() {
        for spec in [
            PolicySpec::OnOff,
            PolicySpec::IdleWaiting,
            PolicySpec::IdleWaitingM1,
            PolicySpec::IdleWaitingM12,
        ] {
            let space = ParamSpace::for_spec(spec);
            assert!(!space.is_tunable(), "{spec}");
            let grid = space.grid_candidates(&PolicyParams::default());
            assert_eq!(grid.len(), 1);
            assert_eq!(grid[0], PolicyParams::default());
        }
    }

    #[test]
    fn oracle_searches_the_saving_axis_only() {
        let space = ParamSpace::for_spec(PolicySpec::Oracle);
        assert!(space.is_tunable());
        let grid = space.grid_candidates(&PolicyParams::default());
        assert_eq!(grid.len(), 3);
        let savings: Vec<PowerSaving> = grid.iter().map(|p| p.saving).collect();
        assert!(savings.contains(&PowerSaving::BASELINE));
        assert!(savings.contains(&PowerSaving::M12));
    }

    #[test]
    fn windowed_quantile_grid_is_the_cartesian_product() {
        let space = ParamSpace::for_spec(PolicySpec::WindowedQuantile);
        let grid = space.grid_candidates(&PolicyParams::default());
        let windows = space.knobs[0].grid().len();
        let quantiles = space.knobs[1].grid().len();
        assert_eq!(grid.len(), 3 * windows * quantiles);
        // every candidate stays in the valid range
        for p in &grid {
            assert!(p.validate().is_ok(), "{p:?}");
        }
        // extreme corners are present
        assert!(grid.iter().any(|p| p.window == 2 && (p.quantile - 0.05).abs() < 1e-12));
        assert!(grid.iter().any(|p| p.window == 256 && (p.quantile - 0.95).abs() < 1e-12));
    }

    #[test]
    fn learned_policy_spaces_search_their_own_knobs() {
        let bayes = ParamSpace::for_spec(PolicySpec::BayesMixture);
        let grid = bayes.grid_candidates(&PolicyParams::default());
        // savings axis × component counts {2, 3, 4}
        assert_eq!(grid.len(), 3 * 3);
        assert!(grid.iter().all(|p| (2..=4).contains(&p.components)));
        assert!(grid.iter().all(|p| p.validate().is_ok()));
        let bandit = ParamSpace::for_spec(PolicySpec::BanditPolicy);
        assert!(bandit.knobs.iter().any(|k| k.name == Knob::EMA_ALPHA));
        assert!(bandit
            .grid_candidates(&PolicyParams::default())
            .iter()
            .all(|p| p.validate().is_ok()));
    }

    #[test]
    fn log_grid_has_equal_ratios() {
        let knob = Knob {
            name: Knob::TIMEOUT_MS,
            scale: Scale::Log,
            lo: 1.0,
            hi: 1000.0,
            integer: false,
            grid_levels: 4,
        };
        let g = knob.grid();
        assert_eq!(g.len(), 4);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[3] - 1000.0).abs() < 1e-9);
        assert!((g[1] / g[0] - g[2] / g[1]).abs() < 1e-9);
    }

    #[test]
    fn integer_knob_rounds_and_dedupes() {
        let knob = Knob {
            name: Knob::WINDOW,
            scale: Scale::Log,
            lo: 2.0,
            hi: 4.0,
            integer: true,
            grid_levels: 8,
        };
        let g = knob.grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]), "{g:?}");
        assert!(g.iter().all(|v| v.fract() == 0.0));
    }

    #[test]
    fn sampling_is_deterministic_and_in_range() {
        let space = ParamSpace::for_spec(PolicySpec::Timeout);
        let base = PolicyParams::default();
        let mut a = Xoshiro256ss::new(9);
        let mut b = Xoshiro256ss::new(9);
        for _ in 0..200 {
            let pa = space.sample(&base, &mut a);
            let pb = space.sample(&base, &mut b);
            assert_eq!(pa, pb);
            let t = pa.timeout.expect("timeout knob always set").millis();
            assert!((0.5..=5_000.0).contains(&t), "{t}");
            assert!(pa.validate().is_ok());
        }
    }

    #[test]
    fn grid_preserves_base_values_for_foreign_knobs() {
        let base = PolicyParams {
            ema_alpha: 0.42,
            ..PolicyParams::default()
        };
        let grid = ParamSpace::for_spec(PolicySpec::Timeout).grid_candidates(&base);
        assert!(grid.iter().all(|p| (p.ema_alpha - 0.42).abs() < 1e-12));
    }
}
