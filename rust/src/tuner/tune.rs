//! The tuning driver: candidate generation → analytical pre-filter →
//! DES scoring on the train split (on the shared sweep engine) →
//! held-out validation.
//!
//! Determinism contract (the same one every sweep in this repo honours):
//! candidate pools are generated single-threaded from a seeded stream,
//! every DES evaluation is a pure function of `(candidate, gap slice)`,
//! batches run on the [`SweepRunner`] in candidate order, and ties break
//! on candidate id via `f64::total_cmp` — so the trajectory CSV is
//! byte-identical at any `--threads N`.
//!
//! The trace is split **chronologically** (first `split` fraction trains,
//! the rest validates): shuffling gaps would leak the heavy-tail
//! structure the predictors are supposed to discover online.
//!
//! Three hot-path properties keep a tuning run cheap without touching
//! its output:
//!
//! * the parsed trace is loaded once and shared (`Arc<[Duration]>`) —
//!   evaluations slice it, they never copy it;
//! * candidates with identical parameter points are **deduplicated** at
//!   DES time (random pools collide often): one simulation per distinct
//!   point, every duplicate logs the shared score;
//! * successive halving **carries train-prefix state across rungs** via
//!   [`PrefixSim`]: rung `k+1` resumes each survivor's simulation where
//!   rung `k` paused it instead of re-simulating the shared prefix —
//!   bit-identical to from-scratch scoring, roughly half the DES work.

use std::sync::{Arc, Mutex};

use crate::config::loader::SimConfig;
use crate::config::schema::{PolicyParams, PolicySpec};
use crate::energy::analytical::Analytical;
use crate::runner::grid::{derive_seed, Grid};
use crate::runner::SweepRunner;
use crate::strategies::simulate::{simulate_batch, PrefixSim, SimReport};
use crate::strategies::strategy::build_with;
use crate::tuner::emit;
use crate::tuner::objective::{analytical_replay, EvalMetrics, Objective};
use crate::tuner::search::SearchStrategy;
use crate::tuner::space::ParamSpace;
use crate::util::csv::Csv;
use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// Everything a tuning run needs besides the config and the trace.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// The policy whose tunables are searched.
    pub spec: PolicySpec,
    /// Candidate-generation strategy.
    pub search: SearchStrategy,
    /// What to optimize (and any feasibility cap).
    pub objective: Objective,
    /// Candidate budget: the number of candidates that survive the
    /// analytical pre-filter into DES scoring.
    pub budget: usize,
    /// Train fraction of the trace in (0, 1); the rest is held out.
    pub split: f64,
    /// Seed for candidate sampling (grid enumeration ignores it).
    pub seed: u64,
}

impl TuneConfig {
    /// Default candidate budget.
    pub const DEFAULT_BUDGET: usize = 64;
    /// Default train fraction.
    pub const DEFAULT_SPLIT: f64 = 0.7;
    /// Random/halving pools oversample the budget by this factor before
    /// the analytical pre-filter cuts them back.
    pub const OVERSAMPLE: usize = 4;

    /// A tuning run for `spec` with every other field at its default
    /// (successive halving, energy objective, budget 64, 70/30 split).
    pub fn for_spec(spec: PolicySpec) -> TuneConfig {
        TuneConfig {
            spec,
            search: SearchStrategy::Halving,
            objective: Objective::default(),
            budget: Self::DEFAULT_BUDGET,
            split: Self::DEFAULT_SPLIT,
            seed: 0,
        }
    }
}

/// Why a tuning run could not start.
#[derive(Debug, thiserror::Error)]
pub enum TuneError {
    /// The trace has too few gaps to split into train + validation.
    #[error("trace has only {have} gap(s); tuning needs at least 4 to split into train and validation")]
    TraceTooShort {
        /// Gaps present in the trace.
        have: usize,
    },
    /// The split fraction is outside (0, 1).
    #[error("--split must be strictly inside (0, 1) (got {split}); it is the train fraction of the trace")]
    BadSplit {
        /// The rejected fraction.
        split: f64,
    },
    /// A zero candidate budget.
    #[error("--budget must be at least 1 candidate")]
    BadBudget,
}

/// One numbered candidate of the search pool.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Stable id: 0 is always the un-tuned base params; generation order
    /// after that. Ties on score break toward the lower id.
    pub id: usize,
    /// The parameter point.
    pub params: PolicyParams,
}

/// One evaluation in the search trajectory (one CSV row).
#[derive(Debug, Clone)]
pub struct TrajectoryPoint {
    /// Which stage produced the row: `prefilter`, `search`, `rung<k>`,
    /// `final` or `validation`.
    pub stage: String,
    /// Global evaluation counter (CSV row order).
    pub eval: usize,
    /// Candidate id the row scores.
    pub candidate: usize,
    /// The candidate's parameter point.
    pub params: PolicyParams,
    /// Gaps the evaluation ran over.
    pub gaps: usize,
    /// The objective score (analytical mJ/gap for `prefilter` rows, the
    /// minimized objective for DES rows).
    pub score: f64,
    /// DES metrics; `None` for analytical pre-filter rows.
    pub metrics: Option<EvalMetrics>,
}

/// A scored evaluation of one parameter point.
#[derive(Debug, Clone, Copy)]
pub struct ScoreCard {
    /// The minimized objective score.
    pub score: f64,
    /// The underlying DES metrics.
    pub metrics: EvalMetrics,
}

/// The result of a tuning run.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// The tuned policy.
    pub spec: PolicySpec,
    /// Objective the scores below minimize.
    pub objective: Objective,
    /// The winning parameter point (never worse than the base point on
    /// the train split, by construction).
    pub best: PolicyParams,
    /// The un-tuned base point (the config's `policy_params`).
    pub base: PolicyParams,
    /// Best point scored on the train split.
    pub best_train: ScoreCard,
    /// Best point scored on the held-out split.
    pub best_val: ScoreCard,
    /// Base point scored on the train split.
    pub base_train: ScoreCard,
    /// Base point scored on the held-out split.
    pub base_val: ScoreCard,
    /// Every evaluation, in execution order.
    pub trajectory: Vec<TrajectoryPoint>,
    /// Candidates dropped by the analytical pre-filter.
    pub pruned: usize,
    /// Pool size before pruning.
    pub pool: usize,
    /// Gaps in the train split.
    pub train_gaps: usize,
    /// Gaps in the validation split.
    pub val_gaps: usize,
}

impl TuneOutcome {
    /// Validation-minus-train score of the best point: positive means the
    /// tuned params look worse out-of-sample (overfit), ≈0 means the
    /// trace splits are statistically alike.
    pub fn overfit_gap(&self) -> f64 {
        self.best_val.score - self.best_train.score
    }

    /// Whether the tuned point beats the base point on the held-out
    /// split (the deployment-relevant comparison).
    pub fn beats_base_on_validation(&self) -> bool {
        self.best_val.score <= self.base_val.score
    }

    /// The search trajectory as CSV (`repro tune --csv`). Pre-filter rows
    /// carry the analytical score and empty DES columns.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "stage",
            "eval",
            "candidate",
            "policy",
            "saving",
            "timeout_ms",
            "ema_alpha",
            "window",
            "quantile",
            "gaps",
            "score",
            "energy_mj_per_item",
            "lifetime_h",
            "late_rate",
            "items",
        ]);
        for p in &self.trajectory {
            let (energy, lifetime, late, items) = match &p.metrics {
                Some(m) => (
                    format!("{}", m.energy_mj_per_item),
                    format!("{}", m.lifetime_h),
                    format!("{}", m.late_rate),
                    m.items.to_string(),
                ),
                None => (String::new(), String::new(), String::new(), String::new()),
            };
            csv.row(&[
                p.stage.clone(),
                p.eval.to_string(),
                p.candidate.to_string(),
                self.spec.name().to_string(),
                emit::saving_name(p.params.saving).to_string(),
                p.params
                    .timeout
                    .map(|t| format!("{}", t.millis()))
                    .unwrap_or_default(),
                format!("{}", p.params.ema_alpha),
                p.params.window.to_string(),
                format!("{}", p.params.quantile),
                p.gaps.to_string(),
                format!("{}", p.score),
                energy,
                lifetime,
                late,
                items,
            ]);
        }
        csv
    }

    /// Human-readable summary (the `repro tune` report body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "tuned {} over {} train / {} validation gaps ({} candidates, {} pruned analytically, {} DES evaluations)\n",
            self.spec.name(),
            self.train_gaps,
            self.val_gaps,
            self.pool,
            self.pruned,
            self.trajectory.iter().filter(|p| p.metrics.is_some()).count(),
        ));
        out.push_str(&format!(
            "best params:  {}\n",
            emit::params_label(self.spec, &self.best)
        ));
        out.push_str(&format!(
            "train:        tuned {:.4} vs default {:.4} ({})\n",
            self.best_train.score,
            self.base_train.score,
            self.objective.label()
        ));
        out.push_str(&format!(
            "validation:   tuned {:.4} vs default {:.4} (overfit gap {:+.4})\n",
            self.best_val.score,
            self.base_val.score,
            self.overfit_gap()
        ));
        out
    }
}

/// Collapse a DES report into the objective's [`ScoreCard`].
fn score_report(config: &SimConfig, objective: &Objective, report: &SimReport) -> ScoreCard {
    let items = report.items.max(1);
    let energy_mj_per_item = report.energy_exact.millijoules() / items as f64;
    // Eq 4 extrapolated: the observed span scales by budget/energy.
    let lifetime_h = if report.energy_exact.joules() > 0.0 {
        report.sim_time.secs() * config.workload.energy_budget.joules()
            / report.energy_exact.joules()
            / 3600.0
    } else {
        0.0
    };
    let metrics = EvalMetrics {
        energy_mj_per_item,
        lifetime_h,
        late_rate: report.late_requests as f64 / items as f64,
        items: report.items,
    };
    ScoreCard {
        score: objective.score(&metrics),
        metrics,
    }
}

/// Exact-identity key of a parameter point (f64 fields compared by
/// bits), used to deduplicate candidates before DES time is spent.
type ParamsKey = (u8, bool, u64, u64, usize, u64, u64, usize, Option<[u8; 64]>);

fn params_key(p: &PolicyParams) -> ParamsKey {
    (
        (p.saving.method1 as u8) | ((p.saving.method2 as u8) << 1),
        p.timeout.is_some(),
        p.timeout.map(|t| t.secs().to_bits()).unwrap_or(0),
        p.ema_alpha.to_bits(),
        p.window,
        p.quantile.to_bits(),
        p.seed,
        p.components,
        p.table.map(|t| t.0),
    )
}

/// Score one parameter point on a gap slice with the full DES: replay the
/// gaps once (no cycling: the item cap is `gaps + 1`, so exactly one
/// pass) on the batched structure-of-arrays kernel — bit-identical to
/// the scalar `TraceReplay` run — then collapse the report per the
/// objective.
pub fn evaluate(
    config: &SimConfig,
    model: &Analytical,
    spec: PolicySpec,
    params: &PolicyParams,
    objective: &Objective,
    gaps: &[Duration],
) -> ScoreCard {
    assert!(!gaps.is_empty(), "evaluation needs at least one gap");
    let mut capped = config.clone();
    capped.workload.max_items = Some(gaps.len() as u64 + 1);
    let mut policy = build_with(spec, model, params);
    let report = simulate_batch(&capped, policy.as_mut(), gaps);
    score_report(config, objective, &report)
}

/// Search the `tc.spec` tunable space on `gaps`, scoring via the DES on
/// `runner`. The config's own `policy_params` are the base point:
/// candidate 0, the pre-filter's protected survivor, and the fallback
/// winner if nothing beats it on the train split.
///
/// The trace arrives `Arc`-shared: every evaluation slices it in place
/// (and the halving rungs resume [`PrefixSim`]s over it), so a tuning
/// run copies the parsed trace zero times.
pub fn tune(
    config: &SimConfig,
    tc: &TuneConfig,
    gaps: &Arc<[Duration]>,
    runner: &SweepRunner,
) -> Result<TuneOutcome, TuneError> {
    if gaps.len() < 4 {
        return Err(TuneError::TraceTooShort { have: gaps.len() });
    }
    if !(tc.split.is_finite() && tc.split > 0.0 && tc.split < 1.0) {
        return Err(TuneError::BadSplit { split: tc.split });
    }
    if tc.budget == 0 {
        return Err(TuneError::BadBudget);
    }
    let train_len = ((gaps.len() as f64 * tc.split).round() as usize).clamp(1, gaps.len() - 1);
    let (train, val) = gaps.split_at(train_len);
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let space = ParamSpace::for_spec(tc.spec);
    let base = config.workload.params;

    // --- candidate pool (single-threaded, seeded → order is canonical);
    // a policy with nothing to search keeps only the base point
    let mut pool: Vec<Candidate> = vec![Candidate { id: 0, params: base }];
    if space.is_tunable() {
        match tc.search {
            SearchStrategy::Grid => {
                for params in space.grid_candidates(&base) {
                    pool.push(Candidate {
                        id: pool.len(),
                        params,
                    });
                }
            }
            SearchStrategy::Random | SearchStrategy::Halving => {
                let mut rng = Xoshiro256ss::new(derive_seed(tc.seed, 0x7u64));
                let n = tc.budget.saturating_mul(TuneConfig::OVERSAMPLE);
                for _ in 0..n {
                    let params = space.sample(&base, &mut rng);
                    pool.push(Candidate {
                        id: pool.len(),
                        params,
                    });
                }
            }
        }
    }
    let pool_size = pool.len();

    let mut trajectory: Vec<TrajectoryPoint> = Vec::new();
    let mut eval_counter = 0usize;

    // --- analytical pre-filter: rank the pool with closed-form gap costs
    // (and the analytical late-rate proxy, when the objective caps it)
    // and keep `budget` candidates (the base point always survives).
    let mut pruned = 0usize;
    if pool.len() > tc.budget {
        let grid = Grid::new(pool.clone());
        let scores = runner.run(&grid, |cell| {
            let est = analytical_replay(&model, tc.spec, &cell.params.params, train);
            tc.objective.prefilter_score(&est)
        });
        for (cand, score) in pool.iter().zip(&scores) {
            trajectory.push(TrajectoryPoint {
                stage: "prefilter".into(),
                eval: eval_counter,
                candidate: cand.id,
                params: cand.params,
                gaps: train.len(),
                score: *score,
                metrics: None,
            });
            eval_counter += 1;
        }
        let mut order: Vec<usize> = (1..pool.len()).collect();
        order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
        let mut keep: Vec<usize> = vec![0];
        keep.extend(order.into_iter().take(tc.budget.saturating_sub(1)));
        keep.sort_unstable();
        pruned = pool.len() - keep.len();
        pool = keep.into_iter().map(|i| pool[i]).collect();
    }

    // --- DES scoring on the train split
    let mut search = Search {
        config,
        tc,
        model: &model,
        runner,
        gaps: gaps.clone(),
        train,
        val,
        trajectory,
        eval_counter,
        full: std::collections::BTreeMap::new(),
        sims: std::collections::BTreeMap::new(),
    };

    let best_id: usize = match tc.search {
        SearchStrategy::Grid | SearchStrategy::Random => {
            let cards = search.eval_batch(&pool, train.len(), "search");
            argmin(&pool, &cards)
        }
        SearchStrategy::Halving => {
            let mut survivors = pool.clone();
            // start on a prefix sized so the halvings land on the full split
            let halvings = (survivors.len().max(2) as f64).log2().ceil() as u32;
            let mut g = (train.len() >> halvings.min(4)).max(16.min(train.len()));
            let mut rung = 0usize;
            loop {
                let cards = search.eval_batch(&survivors, g, &format!("rung{rung}"));
                if survivors.len() <= 2 && g == train.len() {
                    break argmin(&survivors, &cards);
                }
                if survivors.len() > 2 {
                    let mut order: Vec<usize> = (0..survivors.len()).collect();
                    order.sort_by(|&a, &b| {
                        cards[a]
                            .score
                            .total_cmp(&cards[b].score)
                            .then(survivors[a].id.cmp(&survivors[b].id))
                    });
                    let mut kept: Vec<usize> = order[..survivors.len().div_ceil(2)].to_vec();
                    kept.sort_unstable();
                    survivors = kept.into_iter().map(|i| survivors[i]).collect();
                }
                g = (g * 2).min(train.len());
                rung += 1;
            }
        }
    };

    // --- final train scores for the winner and the base point (cached if
    // the search already ran them on the full split), then validation.
    let best_cand = pool
        .iter()
        .copied()
        .find(|c| c.id == best_id)
        .expect("winner comes from the pool");
    let base_cand = Candidate { id: 0, params: base };
    let mut best_train = search.ensure_full(best_cand);
    let base_train = search.ensure_full(base_cand);

    // The base point is part of the pool, so the tuned point can never be
    // worse than it on the train split; enforce it explicitly in case the
    // search eliminated the base early on a short rung.
    let mut best_cand = best_cand;
    if base_train.score < best_train.score {
        best_cand = base_cand;
        best_train = base_train;
    }

    let best_val = search.validate(best_cand);
    let base_val = search.validate(base_cand);

    Ok(TuneOutcome {
        spec: tc.spec,
        objective: tc.objective,
        best: best_cand.params,
        base,
        best_train,
        best_val,
        base_train,
        base_val,
        trajectory: search.trajectory,
        pruned,
        pool: pool_size,
        train_gaps: train.len(),
        val_gaps: val.len(),
    })
}

/// The mutable scoring state of one tuning run: the shared inputs, the
/// trajectory log, the cache of full-train scores by candidate id, and
/// the pausable per-candidate simulations that carry train-prefix state
/// across successive-halving rungs.
struct Search<'a> {
    config: &'a SimConfig,
    tc: &'a TuneConfig,
    model: &'a Analytical,
    runner: &'a SweepRunner,
    /// The whole shared trace (train prefix + validation tail).
    gaps: Arc<[Duration]>,
    train: &'a [Duration],
    val: &'a [Duration],
    trajectory: Vec<TrajectoryPoint>,
    eval_counter: usize,
    full: std::collections::BTreeMap<usize, ScoreCard>,
    /// One pausable DES per candidate id that has reached DES scoring;
    /// rung `k+1` resumes where rung `k` paused instead of re-simulating
    /// the shared prefix. `Mutex` because sweep workers advance disjoint
    /// sims in parallel (each cell locks only its own).
    sims: std::collections::BTreeMap<usize, Mutex<PrefixSim>>,
}

impl Search<'_> {
    /// Score `cands` on the first `prefix` train gaps via the DES on the
    /// sweep runner, returning cards in candidate order. Full-train
    /// evaluations are cached by candidate id (no re-simulation, no
    /// duplicate trajectory rows); identical parameter points are
    /// deduplicated (one simulation per distinct point, every duplicate
    /// logs the shared score); and each candidate's simulation resumes
    /// from the previous rung's prefix.
    fn eval_batch(&mut self, cands: &[Candidate], prefix: usize, stage: &str) -> Vec<ScoreCard> {
        let is_full = prefix == self.train.len();
        let todo: Vec<Candidate> = if is_full {
            cands
                .iter()
                .filter(|c| !self.full.contains_key(&c.id))
                .copied()
                .collect()
        } else {
            cands.to_vec()
        };
        // dedupe: one representative (the first occurrence) per distinct
        // parameter point; duplicates share its card
        let mut reps: Vec<Candidate> = Vec::new();
        let mut rep_of: std::collections::BTreeMap<ParamsKey, usize> =
            std::collections::BTreeMap::new();
        for cand in &todo {
            rep_of.entry(params_key(&cand.params)).or_insert_with(|| {
                reps.push(*cand);
                reps.len() - 1
            });
        }
        // every representative needs a live pausable simulation
        for rep in &reps {
            self.sims.entry(rep.id).or_insert_with(|| {
                Mutex::new(PrefixSim::new(
                    self.config,
                    build_with(self.tc.spec, self.model, &rep.params),
                    self.gaps.clone(),
                ))
            });
        }
        // advance the representatives' sims to this rung's prefix in
        // parallel — every cell locks only its own sim, so results are
        // a pure function of (candidate, prefix) and stay byte-identical
        // at any thread count
        let grid = Grid::new(reps);
        let (config, tc, sims) = (self.config, self.tc, &self.sims);
        let rep_cards: Vec<ScoreCard> = self.runner.run(&grid, |cell| {
            let mut sim = sims
                .get(&cell.params.id)
                .expect("representative sim created above")
                .lock()
                .expect("sim lock poisoned");
            let report = sim.advance_to(prefix);
            score_report(config, &tc.objective, &report)
        });
        let rep_card = |cand: &Candidate| rep_cards[rep_of[&params_key(&cand.params)]];
        let mut fresh: std::collections::BTreeMap<usize, ScoreCard> =
            std::collections::BTreeMap::new();
        for cand in &todo {
            let card = rep_card(cand);
            self.log(stage, *cand, prefix, card);
            fresh.insert(cand.id, card);
            if is_full {
                self.full.insert(cand.id, card);
            }
        }
        if is_full {
            // the full-train card is cached; there is nothing left to
            // resume, so drop the pausable sims — memory then scales with
            // the halving survivor count, not the whole candidate pool
            // (grid/random searches score everything at full in one batch)
            for cand in &todo {
                self.sims.remove(&cand.id);
            }
        }
        cands
            .iter()
            .map(|c| {
                fresh
                    .get(&c.id)
                    .or_else(|| if is_full { self.full.get(&c.id) } else { None })
                    .copied()
                    .expect("every candidate is evaluated or cached")
            })
            .collect()
    }

    /// The full-train score of `cand`, from cache or one `final` eval.
    fn ensure_full(&mut self, cand: Candidate) -> ScoreCard {
        if let Some(card) = self.full.get(&cand.id) {
            return *card;
        }
        self.eval_batch(&[cand], self.train.len(), "final")[0]
    }

    /// Score `cand` on the held-out split and log a `validation` row.
    fn validate(&mut self, cand: Candidate) -> ScoreCard {
        let card = evaluate(
            self.config,
            self.model,
            self.tc.spec,
            &cand.params,
            &self.tc.objective,
            self.val,
        );
        self.log("validation", cand, self.val.len(), card);
        card
    }

    /// Append one trajectory row.
    fn log(&mut self, stage: &str, cand: Candidate, gaps: usize, card: ScoreCard) {
        self.trajectory.push(TrajectoryPoint {
            stage: stage.to_string(),
            eval: self.eval_counter,
            candidate: cand.id,
            params: cand.params,
            gaps,
            score: card.score,
            metrics: Some(card.metrics),
        });
        self.eval_counter += 1;
    }
}

/// Index of the minimum score, ties toward the lower candidate id.
fn argmin(cands: &[Candidate], cards: &[ScoreCard]) -> usize {
    let mut best = 0usize;
    for i in 1..cands.len() {
        let better = cards[i]
            .score
            .total_cmp(&cards[best].score)
            .then(cands[i].id.cmp(&cands[best].id))
            .is_lt();
        if better {
            best = i;
        }
    }
    cands[best].id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::device::rails::PowerSaving;
    use crate::energy::crossover;

    fn periodic(ms: f64, n: usize) -> Arc<[Duration]> {
        vec![Duration::from_millis(ms); n].into()
    }

    fn tc(spec: PolicySpec, search: SearchStrategy) -> TuneConfig {
        TuneConfig {
            search,
            budget: 24,
            seed: 5,
            ..TuneConfig::for_spec(spec)
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let short = periodic(40.0, 2);
        assert!(matches!(
            tune(&cfg, &tc(PolicySpec::Timeout, SearchStrategy::Grid), &short, &runner),
            Err(TuneError::TraceTooShort { have: 2 })
        ));
        let gaps = periodic(40.0, 16);
        let mut bad = tc(PolicySpec::Timeout, SearchStrategy::Grid);
        bad.split = 1.5;
        assert!(matches!(
            tune(&cfg, &bad, &gaps, &runner),
            Err(TuneError::BadSplit { .. })
        ));
        let mut bad = tc(PolicySpec::Timeout, SearchStrategy::Grid);
        bad.budget = 0;
        assert!(matches!(tune(&cfg, &bad, &gaps, &runner), Err(TuneError::BadBudget)));
    }

    #[test]
    fn tuned_never_loses_to_the_base_point_on_train() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(40.0, 24);
        for search in SearchStrategy::ALL {
            let out = tune(&cfg, &tc(PolicySpec::WindowedQuantile, search), &gaps, &runner)
                .unwrap();
            assert!(
                out.best_train.score <= out.base_train.score,
                "{search}: tuned {} vs base {}",
                out.best_train.score,
                out.base_train.score
            );
        }
    }

    #[test]
    fn results_are_identical_at_any_thread_count() {
        let cfg = paper_default();
        // a trace that actually separates candidates
        let gaps: Arc<[Duration]> = (0..48)
            .map(|i| Duration::from_millis(if i % 6 == 5 { 700.0 } else { 15.0 }))
            .collect::<Vec<_>>()
            .into();
        for search in SearchStrategy::ALL {
            let conf = tc(PolicySpec::WindowedQuantile, search);
            let serial = tune(&cfg, &conf, &gaps, &SweepRunner::single()).unwrap();
            let parallel = tune(&cfg, &conf, &gaps, &SweepRunner::new(8)).unwrap();
            assert_eq!(serial.best, parallel.best, "{search}");
            assert_eq!(
                serial.to_csv().render(),
                parallel.to_csv().render(),
                "{search}: trajectory must be byte-identical"
            );
        }
    }

    /// Convergence sanity: on a periodic trace the tuned `Timeout` must
    /// land on the closed-form crossover's side of the decision — a
    /// timeout the period never reaches (pure idling) below the
    /// crossover, a near-zero timeout (buy immediately) above it. The
    /// two test periods bracket the 499.06 ms M1+2 crossover.
    #[test]
    fn tuned_timeout_converges_to_the_crossover_decision() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let cross_m12 =
            crossover::asymptotic(&model, crate::device::rails::RailSet::idle_power(PowerSaving::M12));
        assert!((cross_m12.millis() - 499.06).abs() < 0.2);

        // 450 ms < crossover: renting (idling) through every gap is
        // optimal, so the tuned timeout must exceed the period.
        let below = tune(
            &cfg,
            &tc(PolicySpec::Timeout, SearchStrategy::Grid),
            &periodic(450.0, 24),
            &runner,
        )
        .unwrap();
        assert_eq!(below.best.saving, PowerSaving::M12);
        let t_below = below.best.timeout.expect("timeout knob set").millis();
        assert!(t_below > 450.0, "below crossover: tuned timeout {t_below} must out-rent the period");

        // 550 ms > crossover: buying (powering off) immediately is
        // optimal, so the tuned timeout must be far below the period.
        let above = tune(
            &cfg,
            &tc(PolicySpec::Timeout, SearchStrategy::Grid),
            &periodic(550.0, 24),
            &runner,
        )
        .unwrap();
        let t_above = above.best.timeout.expect("timeout knob set").millis();
        assert!(t_above < 50.0, "above crossover: tuned timeout {t_above} must buy early");
        // and the tuned point beats the base (break-even τ) on validation
        assert!(above.beats_base_on_validation());
    }

    #[test]
    fn windowed_quantile_tuning_beats_defaults_on_a_bursty_holdout() {
        // The acceptance-criteria scenario in miniature: bursts of short
        // gaps + long silences. The default q=0.9 reads the silence tail
        // and powers off through bursts; tuning must find a point that
        // idles through bursts instead, and it must hold up out-of-sample.
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps: Arc<[Duration]> = crate::coordinator::tracegen::generate_durations(
            crate::coordinator::tracegen::TraceKind::BurstyIot,
            128,
            40.0,
            1,
        )
        .into();
        let out = tune(
            &cfg,
            &tc(PolicySpec::WindowedQuantile, SearchStrategy::Halving),
            &gaps,
            &runner,
        )
        .unwrap();
        assert!(
            out.best_val.score < out.base_val.score,
            "tuned {} must beat default {} on the held-out split",
            out.best_val.score,
            out.base_val.score
        );
        assert!(out.val_gaps >= 1 && out.train_gaps + out.val_gaps == 128);
    }

    /// The halving path resumes each candidate's DES across rungs; its
    /// final train score must be bit-identical to a from-scratch
    /// `evaluate` of the same point on the full train split.
    #[test]
    fn resumed_halving_scores_equal_from_scratch_evaluation() {
        let cfg = paper_default();
        let runner = SweepRunner::new(4);
        let gaps: Arc<[Duration]> = (0..64)
            .map(|i| Duration::from_millis(if i % 5 == 4 { 900.0 } else { 20.0 }))
            .collect::<Vec<_>>()
            .into();
        let conf = tc(PolicySpec::WindowedQuantile, SearchStrategy::Halving);
        let out = tune(&cfg, &conf, &gaps, &runner).unwrap();
        let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
        let train = &gaps[..out.train_gaps];
        let scratch = evaluate(&cfg, &model, conf.spec, &out.best, &conf.objective, train);
        assert_eq!(
            out.best_train.score.to_bits(),
            scratch.score.to_bits(),
            "resumed {} vs scratch {}",
            out.best_train.score,
            scratch.score
        );
        assert_eq!(out.best_train.metrics.items, scratch.metrics.items);
        assert_eq!(
            out.best_train.metrics.energy_mj_per_item.to_bits(),
            scratch.metrics.energy_mj_per_item.to_bits()
        );
    }

    /// Identical parameter points are simulated once: every duplicate
    /// candidate's trajectory rows carry the exact shared score.
    #[test]
    fn duplicate_candidates_share_their_representative_score() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(40.0, 16);
        // rows with equal (params, gaps) must carry bit-equal scores —
        // with dedupe they literally come from one simulation
        let mut conf = tc(PolicySpec::Timeout, SearchStrategy::Random);
        conf.budget = 12;
        let out = tune(&cfg, &conf, &gaps, &runner).unwrap();
        let des_rows: Vec<_> = out.trajectory.iter().filter(|p| p.metrics.is_some()).collect();
        for a in &des_rows {
            for b in &des_rows {
                if a.gaps == b.gaps && a.params == b.params {
                    assert_eq!(
                        a.score.to_bits(),
                        b.score.to_bits(),
                        "equal points must share one simulation's score"
                    );
                }
            }
        }
    }

    #[test]
    fn prefilter_prunes_only_above_budget_and_protects_the_base() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(40.0, 16);
        // grid for windowed-quantile is 3×6×7 = 126 (+1 base) > budget 24
        let out = tune(
            &cfg,
            &tc(PolicySpec::WindowedQuantile, SearchStrategy::Grid),
            &gaps,
            &runner,
        )
        .unwrap();
        assert!(out.pruned > 0, "grid larger than budget must prune");
        assert!(out.trajectory.iter().any(|p| p.stage == "prefilter"));
        // candidate 0 (the base point) always reaches DES scoring
        assert!(out
            .trajectory
            .iter()
            .any(|p| p.candidate == 0 && p.metrics.is_some()));
        // static policy: nothing to search, nothing pruned
        let out = tune(
            &cfg,
            &tc(PolicySpec::IdleWaiting, SearchStrategy::Grid),
            &gaps,
            &runner,
        )
        .unwrap();
        assert_eq!(out.pruned, 0);
        assert_eq!(out.best, PolicyParams::default());
    }

    #[test]
    fn csv_has_the_published_schema_and_all_stages() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(40.0, 32);
        let out = tune(
            &cfg,
            &tc(PolicySpec::WindowedQuantile, SearchStrategy::Halving),
            &gaps,
            &runner,
        )
        .unwrap();
        let csv = out.to_csv().render();
        assert!(csv.starts_with(
            "stage,eval,candidate,policy,saving,timeout_ms,ema_alpha,window,quantile,gaps,\
             score,energy_mj_per_item,lifetime_h,late_rate,items"
        ));
        assert_eq!(out.to_csv().n_rows(), out.trajectory.len());
        assert!(csv.contains("validation"));
        assert!(csv.contains("rung0"));
        assert!(!out.render().is_empty());
    }

    #[test]
    fn late_rate_cap_yields_a_feasible_winner() {
        // 30 ms gaps: any timeout that fires leaves the fabric busy past
        // the next arrival, so a zero-tolerance cap must steer the search
        // (pre-filter included) to a point that never powers off.
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(30.0, 24);
        let mut conf = tc(PolicySpec::Timeout, SearchStrategy::Grid);
        conf.budget = 8; // smaller than the 25-candidate grid → real pruning
        conf.objective = Objective {
            kind: crate::tuner::objective::ObjectiveKind::Energy,
            max_late_rate: Some(0.0),
        };
        let out = tune(&cfg, &conf, &gaps, &runner).unwrap();
        assert!(out.pruned > 0);
        assert!(out.best_train.score.is_finite());
        assert_eq!(out.best_val.metrics.late_rate, 0.0);
        // the constraint-aware pre-filter kept feasible non-base
        // candidates alive into DES scoring
        assert!(out
            .trajectory
            .iter()
            .any(|p| p.stage == "search" && p.candidate != 0 && p.score.is_finite()));
    }

    #[test]
    fn lifetime_objective_agrees_with_energy_on_rankings() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let gaps = periodic(600.0, 24);
        let energy = tune(&cfg, &tc(PolicySpec::Timeout, SearchStrategy::Grid), &gaps, &runner)
            .unwrap();
        let mut lt = tc(PolicySpec::Timeout, SearchStrategy::Grid);
        lt.objective = Objective {
            kind: crate::tuner::objective::ObjectiveKind::Lifetime,
            max_late_rate: None,
        };
        let lifetime = tune(&cfg, &lt, &gaps, &runner).unwrap();
        assert_eq!(energy.best, lifetime.best);
        assert!(lifetime.best_train.score < 0.0, "lifetime scores are negated hours");
    }
}
