//! Offline training for the contextual bandit gap policy
//! (`repro train`): replay a trace's train split through a cold
//! [`BanditPolicy`], freeze the greedy per-cell action table it learned,
//! and emit it as a `policy_params` fragment (`--emit`, the same
//! round-trippable surface as `repro tune --emit`) that `repro serve`,
//! `repro exp4` and the fleet classes can load back.
//!
//! Train/eval split: the table is **fit** on the chronological train
//! prefix (the bandit observes each gap once, full-information
//! counterfactual updates, no exploration noise) and **scored** on it by
//! a from-scratch DES evaluation of the frozen `(alpha, table)` point;
//! the winner among the candidate feature-smoothing alphas is then
//! reported against the held-out tail — the same anti-overfit discipline
//! `tuner::tune` applies, specialized to the bandit's two-phase
//! (fit table, then deploy frozen) lifecycle.
//!
//! Determinism: the candidate-alpha ladder is a pure log grid, the
//! fit replay is sequential per candidate, and scoring runs on the
//! [`SweepRunner`](crate::runner::SweepRunner) grid in candidate order —
//! byte-identical output at any `--threads N`.

use std::sync::Arc;

use crate::config::loader::SimConfig;
use crate::config::schema::{PolicyParams, PolicySpec, PolicyTable};
use crate::energy::analytical::Analytical;
use crate::runner::grid::Grid;
use crate::runner::SweepRunner;
use crate::strategies::learned::BanditPolicy;
use crate::strategies::strategy::{GapContext, Policy};
use crate::tuner::emit;
use crate::tuner::objective::Objective;
use crate::tuner::tune::{evaluate, ScoreCard, TuneError};
use crate::util::csv::Csv;
use crate::util::units::Duration;

/// Everything a training run needs besides the config and the trace.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of candidate feature-smoothing alphas on the log ladder.
    pub budget: usize,
    /// Train fraction of the trace in (0, 1); the rest is held out.
    pub split: f64,
    /// Stored into the emitted params (the bandit itself is RNG-free).
    pub seed: u64,
    /// What the candidate scores minimize.
    pub objective: Objective,
}

impl TrainConfig {
    /// Default candidate-alpha budget.
    pub const DEFAULT_BUDGET: usize = 8;
    /// Default train fraction (matches `tune`).
    pub const DEFAULT_SPLIT: f64 = 0.7;
    /// Alpha ladder endpoints: sluggish features to track-newest.
    pub const ALPHA_LO: f64 = 0.02;
    /// Upper ladder endpoint.
    pub const ALPHA_HI: f64 = 1.0;
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            budget: Self::DEFAULT_BUDGET,
            split: Self::DEFAULT_SPLIT,
            seed: 0,
            objective: Objective::default(),
        }
    }
}

/// One scored candidate of the alpha ladder (one CSV row).
#[derive(Debug, Clone)]
pub struct TrainPoint {
    /// Ladder position (CSV row order).
    pub candidate: usize,
    /// The feature-smoothing alpha fitted and scored.
    pub alpha: f64,
    /// The greedy table the fit replay froze.
    pub table: PolicyTable,
    /// Frozen-point score on the train split.
    pub train: ScoreCard,
}

/// The result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// The deployable parameter point: winning alpha + frozen table.
    pub best: PolicyParams,
    /// Winning candidate's index on the ladder.
    pub best_candidate: usize,
    /// Winning point scored on the train split.
    pub best_train: ScoreCard,
    /// Winning point scored on the held-out split.
    pub best_val: ScoreCard,
    /// The default fixed `Timeout` policy on the same held-out split —
    /// the deployment-relevant baseline the trained table must beat.
    pub timeout_val: ScoreCard,
    /// Every candidate, in ladder order.
    pub candidates: Vec<TrainPoint>,
    /// Gaps in the train split.
    pub train_gaps: usize,
    /// Gaps in the validation split.
    pub val_gaps: usize,
}

impl TrainOutcome {
    /// Whether the trained point beats the default `Timeout` baseline on
    /// the held-out split.
    pub fn beats_timeout_on_holdout(&self) -> bool {
        self.best_val.score <= self.timeout_val.score
    }

    /// The candidate ladder as CSV (`repro train --csv`).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "candidate",
            "ema_alpha",
            "gaps",
            "score",
            "energy_mj_per_item",
            "late_rate",
            "items",
            "table",
        ]);
        for p in &self.candidates {
            csv.row(&[
                p.candidate.to_string(),
                format!("{}", p.alpha),
                self.train_gaps.to_string(),
                format!("{}", p.train.score),
                format!("{}", p.train.metrics.energy_mj_per_item),
                format!("{}", p.train.metrics.late_rate),
                p.train.metrics.items.to_string(),
                p.table.render(),
            ]);
        }
        csv
    }

    /// Human-readable summary (the `repro train` report body).
    pub fn render(&self) -> String {
        let trained = self
            .best
            .table
            .map(|t| t.0.iter().filter(|&&a| a != b't').count())
            .unwrap_or(0);
        let mut out = String::new();
        out.push_str(&format!(
            "trained bandit over {} train / {} validation gaps ({} candidate alphas)\n",
            self.train_gaps,
            self.val_gaps,
            self.candidates.len(),
        ));
        out.push_str(&format!(
            "best params:  {} ({} of {} cells learned)\n",
            emit::params_label(PolicySpec::BanditPolicy, &self.best),
            trained,
            PolicyTable::CELLS,
        ));
        out.push_str(&format!(
            "train:        {:.4} | holdout: {:.4} (overfit gap {:+.4})\n",
            self.best_train.score,
            self.best_val.score,
            self.best_val.score - self.best_train.score,
        ));
        out.push_str(&format!(
            "holdout vs default timeout policy: trained {:.4} vs timeout {:.4} ({})\n",
            self.best_val.score,
            self.timeout_val.score,
            if self.beats_timeout_on_holdout() {
                "trained wins"
            } else {
                "timeout wins"
            },
        ));
        out
    }
}

/// Fit replay: run a cold bandit over `gaps` with the exact plan/observe
/// interleaving the online runtimes use (single stream: `queued` 0, the
/// clock advancing by the realized gaps) and freeze its greedy table.
pub fn fit_table(
    model: &Analytical,
    base: &PolicyParams,
    alpha: f64,
    gaps: &[Duration],
) -> PolicyTable {
    let mut policy = BanditPolicy::from_model(model, base.saving, alpha, None);
    let mut now = Duration::ZERO;
    for (i, &gap) in gaps.iter().enumerate() {
        let ctx = GapContext {
            items_done: i as u64 + 1,
            now,
            queued: 0,
        };
        let _ = policy.plan_gap(&ctx);
        policy.observe(gap);
        now = now + gap;
    }
    policy.greedy_table()
}

/// Train the bandit's action table on `gaps`: fit + score one frozen
/// `(alpha, table)` point per ladder candidate, pick the best train
/// score (ties toward the lower ladder index), report it on the held-out
/// tail next to the default `Timeout` baseline.
pub fn train(
    config: &SimConfig,
    tc: &TrainConfig,
    gaps: &Arc<[Duration]>,
    runner: &SweepRunner,
) -> Result<TrainOutcome, TuneError> {
    if gaps.len() < 4 {
        return Err(TuneError::TraceTooShort { have: gaps.len() });
    }
    if !(tc.split.is_finite() && tc.split > 0.0 && tc.split < 1.0) {
        return Err(TuneError::BadSplit { split: tc.split });
    }
    if tc.budget == 0 {
        return Err(TuneError::BadBudget);
    }
    let train_len = ((gaps.len() as f64 * tc.split).round() as usize).clamp(1, gaps.len() - 1);
    let (train, val) = gaps.split_at(train_len);
    let model = Analytical::new(&config.item, config.workload.energy_budget);
    let base = config.workload.params;

    // the candidate ladder: log-spaced alphas, low to high
    let n = tc.budget;
    let denom = n.saturating_sub(1).max(1) as f64;
    let alphas: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64 / denom;
            TrainConfig::ALPHA_LO * (TrainConfig::ALPHA_HI / TrainConfig::ALPHA_LO).powf(t)
        })
        .collect();

    // fit + score every candidate on the sweep runner (candidate order is
    // canonical; each cell is a pure function of its alpha)
    let grid = Grid::new(alphas.clone());
    let points: Vec<(PolicyTable, ScoreCard)> = runner.run(&grid, |cell| {
        let alpha = *cell.params;
        let table = fit_table(&model, &base, alpha, train);
        let params = PolicyParams {
            ema_alpha: alpha,
            table: Some(table),
            seed: tc.seed,
            ..base
        };
        let card = evaluate(
            config,
            &model,
            PolicySpec::BanditPolicy,
            &params,
            &tc.objective,
            train,
        );
        (table, card)
    });
    let candidates: Vec<TrainPoint> = points
        .iter()
        .enumerate()
        .map(|(i, (table, card))| TrainPoint {
            candidate: i,
            alpha: alphas[i],
            table: *table,
            train: *card,
        })
        .collect();
    let mut best_candidate = 0usize;
    for (i, p) in candidates.iter().enumerate() {
        if p.train
            .score
            .total_cmp(&candidates[best_candidate].train.score)
            .is_lt()
        {
            best_candidate = i;
        }
    }
    let winner = &candidates[best_candidate];
    let best = PolicyParams {
        ema_alpha: winner.alpha,
        table: Some(winner.table),
        seed: tc.seed,
        ..base
    };
    let best_val = evaluate(
        config,
        &model,
        PolicySpec::BanditPolicy,
        &best,
        &tc.objective,
        val,
    );
    let timeout_val = evaluate(
        config,
        &model,
        PolicySpec::Timeout,
        &PolicyParams::default(),
        &tc.objective,
        val,
    );
    Ok(TrainOutcome {
        best,
        best_candidate,
        best_train: winner.train,
        best_val,
        timeout_val,
        candidates,
        train_gaps: train.len(),
        val_gaps: val.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::coordinator::tracegen::{generate_durations, TraceKind};

    fn bursty(n: usize, seed: u64) -> Arc<[Duration]> {
        generate_durations(TraceKind::BurstyIot, n, 40.0, seed).into()
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let short: Arc<[Duration]> = vec![Duration::from_millis(40.0); 2].into();
        assert!(matches!(
            train(&cfg, &TrainConfig::default(), &short, &runner),
            Err(TuneError::TraceTooShort { have: 2 })
        ));
        let gaps = bursty(32, 1);
        let bad = TrainConfig {
            split: 0.0,
            ..TrainConfig::default()
        };
        assert!(matches!(
            train(&cfg, &bad, &gaps, &runner),
            Err(TuneError::BadSplit { .. })
        ));
        let bad = TrainConfig {
            budget: 0,
            ..TrainConfig::default()
        };
        assert!(matches!(train(&cfg, &bad, &gaps, &runner), Err(TuneError::BadBudget)));
    }

    #[test]
    fn training_learns_cells_and_is_identical_at_any_thread_count() {
        let cfg = paper_default();
        let gaps = bursty(128, 1);
        let tc = TrainConfig::default();
        let serial = train(&cfg, &tc, &gaps, &SweepRunner::single()).unwrap();
        let parallel = train(&cfg, &tc, &gaps, &SweepRunner::new(8)).unwrap();
        assert_eq!(serial.best, parallel.best);
        assert_eq!(serial.to_csv().render(), parallel.to_csv().render());
        // the fit replay visited cells and learned non-hedge actions
        let table = serial.best.table.expect("training always emits a table");
        assert!(table.0.iter().any(|&a| a != b't'), "{}", table.render());
        assert_eq!(serial.candidates.len(), tc.budget);
        assert!(!serial.render().is_empty());
    }

    #[test]
    fn trained_table_beats_the_timeout_baseline_on_bursty_holdout() {
        // the acceptance-criteria comparison in miniature: on a bursty
        // trace the frozen table idles through bursts and buys at
        // silences, beating the fixed break-even timeout out-of-sample
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let out = train(&cfg, &TrainConfig::default(), &bursty(192, 3), &runner).unwrap();
        assert!(
            out.beats_timeout_on_holdout(),
            "trained {} vs timeout {}",
            out.best_val.score,
            out.timeout_val.score
        );
        assert!(out.best_val.metrics.late_rate <= out.timeout_val.metrics.late_rate);
    }

    #[test]
    fn emitted_fragment_reconstructs_the_trained_policy() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let out = train(&cfg, &TrainConfig::default(), &bursty(96, 2), &runner).unwrap();
        let dir = std::env::temp_dir().join("idlewait_train_emit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trained.yaml");
        std::fs::write(&path, emit::yaml_fragment(PolicySpec::BanditPolicy, &out.best)).unwrap();
        let (spec, loaded) = emit::load_fragment(&path).unwrap();
        assert_eq!(spec, PolicySpec::BanditPolicy);
        assert_eq!(loaded.table, out.best.table);
        assert!((loaded.ema_alpha - out.best.ema_alpha).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_has_the_published_schema() {
        let cfg = paper_default();
        let runner = SweepRunner::single();
        let out = train(&cfg, &TrainConfig::default(), &bursty(48, 1), &runner).unwrap();
        let csv = out.to_csv().render();
        assert!(csv.starts_with(
            "candidate,ema_alpha,gaps,score,energy_mj_per_item,late_rate,items,table"
        ));
        assert_eq!(out.to_csv().n_rows(), out.candidates.len());
    }
}
