//! Trace-driven auto-search over [`PolicyParams`] — the `repro tune`
//! subsystem.
//!
//! The paper's headline wins (40.13× configuration energy, the
//! 89.21/499.06 ms crossovers, 12.39× lifetime) all come from choosing
//! configuration parameters *correctly*; the PR-3 tunable suite made the
//! gap-policy knobs configurable but left picking them to the user. This
//! module closes that loop, DPUConfig-style: given a policy and a gap
//! trace, it searches the policy's tunable space automatically and emits
//! parameters ready for deployment.
//!
//! The pipeline:
//!
//! 1. [`space::ParamSpace`] — which knobs apply to the policy, their
//!    ranges and scales.
//! 2. [`search::SearchStrategy`] — grid, random, or successive halving;
//!    candidate pools come from a seeded stream, so results are
//!    byte-identical at any `--threads N`.
//! 3. [`objective::analytical_replay`] — the closed-form pre-filter
//!    (per-gap energy + an analytical late-rate proxy) that prunes
//!    obviously-dominated candidates before DES time is spent.
//! 4. [`objective::Objective`] — energy per item, projected lifetime, or
//!    either under a late-request-rate feasibility cap.
//! 5. [`tune::tune`] — scores survivors with the real DES
//!    ([`simulate`](crate::strategies::simulate::simulate)) on the
//!    shared [`SweepRunner`](crate::runner::SweepRunner), on a
//!    chronological train split, then reports the overfit gap against
//!    the held-out remainder.
//! 6. [`emit`] — the winning point as a `repro serve` flags line, a
//!    config YAML fragment, and (via [`emit::load_fragment`]) the input
//!    format for per-accelerator tuning in `repro multi`.
//!
//! [`train::train`] (`repro train`) is the offline sibling for the
//! contextual bandit: instead of searching knob values it **fits** the
//! bandit's per-cell action table on the train split and emits the
//! frozen `(alpha, table)` point through the same [`emit`] surfaces.
//!
//! [`PolicyParams`]: crate::config::schema::PolicyParams

pub mod emit;
pub mod objective;
pub mod search;
pub mod space;
pub mod train;
pub mod tune;

pub use emit::{flags_line, load_fragment, params_label, yaml_fragment};
pub use objective::{Objective, ObjectiveKind};
pub use search::SearchStrategy;
pub use space::{Knob, ParamSpace, Scale};
pub use train::{train, TrainConfig, TrainOutcome, TrainPoint};
pub use tune::{tune, TuneConfig, TuneError, TuneOutcome};
