//! Tuning objectives: how one DES evaluation is collapsed into a single
//! comparable score, plus the analytical pre-filter that prunes
//! obviously-dominated candidates before any DES time is spent.
//!
//! Scores are **minimized** and totally ordered via `f64::total_cmp`
//! (with the candidate id as tie-break), so every search strategy is
//! deterministic. An infeasible evaluation (late-request rate above the
//! configured cap) scores `+∞` and can never win.

use crate::config::schema::{PolicyParams, PolicySpec};
use crate::device::rails::{PowerSaving, RailSet};
use crate::energy::analytical::Analytical;
use crate::strategies::replay::{GapBatch, KIND_IDLE, KIND_OFF};
use crate::strategies::strategy::{build_with, GapContext};
use crate::util::units::Duration;

/// What a tuning run optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Minimize mean energy per served item (mJ/item) — the paper's
    /// per-item energy axis (Figs 8–11).
    Energy,
    /// Maximize the projected battery lifetime (Eq 4 extrapolated from
    /// the observed burn rate). On a fixed trace this ranks identically
    /// to [`ObjectiveKind::Energy`]; it differs under a late-rate
    /// constraint and reports in the paper's headline unit (hours).
    Lifetime,
}

impl ObjectiveKind {
    /// Parse a CLI/config objective name.
    pub fn parse(s: &str) -> Option<ObjectiveKind> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "energy" | "energy-per-item" | "mj-per-item" => Some(ObjectiveKind::Energy),
            "lifetime" | "lifetime-h" => Some(ObjectiveKind::Lifetime),
            _ => None,
        }
    }

    /// Canonical name (CSV/report surface).
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::Energy => "energy",
            ObjectiveKind::Lifetime => "lifetime",
        }
    }
}

/// A tuning objective: the quantity to optimize plus an optional
/// late-request-rate feasibility cap (the "energy with a
/// late-request-rate constraint" objective).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objective {
    /// The quantity to optimize.
    pub kind: ObjectiveKind,
    /// Maximum tolerated `late_requests / items`; evaluations above it
    /// score `+∞` (infeasible). `None` = unconstrained.
    pub max_late_rate: Option<f64>,
}

impl Default for Objective {
    fn default() -> Self {
        Objective {
            kind: ObjectiveKind::Energy,
            max_late_rate: None,
        }
    }
}

/// The measured quantities one DES evaluation produces; the
/// [`Objective`] collapses them to a score, the trajectory CSV reports
/// them all.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetrics {
    /// Mean FPGA-side energy per served item (mJ).
    pub energy_mj_per_item: f64,
    /// Projected battery lifetime in hours: observed trace span scaled by
    /// `budget / energy_drawn` (Eq 4 extrapolated to budget exhaustion).
    pub lifetime_h: f64,
    /// Fraction of requests served late (`late_requests / items`).
    pub late_rate: f64,
    /// Items actually served in the evaluation.
    pub items: u64,
}

impl Objective {
    /// Collapse one evaluation to a minimized score; `+∞` = infeasible.
    pub fn score(&self, m: &EvalMetrics) -> f64 {
        if let Some(cap) = self.max_late_rate {
            if m.late_rate > cap {
                return f64::INFINITY;
            }
        }
        match self.kind {
            ObjectiveKind::Energy => m.energy_mj_per_item,
            ObjectiveKind::Lifetime => -m.lifetime_h,
        }
    }

    /// Collapse a pre-filter estimate the same way [`Objective::score`]
    /// collapses a DES evaluation: candidates whose *analytical* late
    /// rate already violates the cap rank `+∞`, so a constrained tuning
    /// run prunes toward feasible cells instead of toward aggressive
    /// power-off points that would all be infeasible in DES scoring.
    /// (Both objective kinds rank the pre-filter by energy: on a fixed
    /// trace projected lifetime is monotone in per-gap energy.)
    pub fn prefilter_score(&self, est: &AnalyticalEstimate) -> f64 {
        if let Some(cap) = self.max_late_rate {
            if est.late_rate > cap {
                return f64::INFINITY;
            }
        }
        est.mean_gap_energy_mj
    }

    /// Human-readable label (`energy`, `energy(late<=0.05)`, …).
    pub fn label(&self) -> String {
        match self.max_late_rate {
            Some(cap) => format!("{}(late<={cap})", self.kind.name()),
            None => self.kind.name().to_string(),
        }
    }
}

/// The closed-form pre-filter estimate of one candidate on one trace:
/// per-gap energy from the paper's model plus the fraction of gaps whose
/// plan leaves the fabric busy past the next arrival (the analytical
/// proxy for the DES's late-request rate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticalEstimate {
    /// Mean per-gap energy (mJ) of the candidate's plan decisions.
    pub mean_gap_energy_mj: f64,
    /// Fraction of gaps shorter than their plan's busy window
    /// (reconfiguration + item latency where power was cut).
    pub late_rate: f64,
}

/// Replay a candidate's *plan decisions* against the trace with the
/// closed-form gap costs of the paper's model — idle gaps at the Table 3
/// rail power, power-offs at the power-cycle + reconfiguration "buy"
/// cost, expired timers at idle-to-the-timer plus the buy cost — and
/// estimate lateness from each plan's busy window. No DES, no board: a
/// few arithmetic operations per gap, so a large candidate pool can be
/// ranked cheaply and the obviously-dominated cells (e.g. quantile
/// points that power off through every burst) pruned before the DES
/// pass.
///
/// This is a ranking heuristic, not the final score: the DES additionally
/// accounts item phases, the flash floor during configuration, monitor
/// error and queueing cascades — which is exactly why survivors are
/// re-scored by the DES rather than trusted from here.
pub fn analytical_replay(
    model: &Analytical,
    spec: PolicySpec,
    params: &PolicyParams,
    gaps: &[Duration],
) -> AnalyticalEstimate {
    if gaps.is_empty() {
        return AnalyticalEstimate {
            mean_gap_energy_mj: 0.0,
            late_rate: 0.0,
        };
    }
    let mut policy = build_with(spec, model, params);
    // Plan the whole trace through the batched entry point. Deliberately
    // `plan_gaps`, not `decide_batch`: the pre-filter replays *blind*
    // decisions, so the oracle must not see the gaps here either. The
    // plan/observe interleaving inside `plan_gaps` matches the old scalar
    // loop exactly, so learned policies emit the identical plan sequence.
    let ctxs: Vec<GapContext> = (0..gaps.len())
        .map(|i| GapContext {
            items_done: i as u64 + 1,
            now: Duration::ZERO,
            queued: 0,
        })
        .collect();
    let mut batch = GapBatch::default();
    policy.plan_gaps(&ctxs, gaps, &mut batch);

    let e_buy_mj = (model.item.e_item_onoff() - model.item.e_active).millijoules();
    let latency = model.item.latency_without_config.secs();
    let busy_with_config = model.item.latency_with_config.secs();
    // Table 3 idle power per saving-combo index, hoisted out of the loop
    // (the combo index IS the bit pattern, so this lookup is exact).
    let mut idle_mw = [0.0f64; 4];
    for (bits, slot) in idle_mw.iter_mut().enumerate() {
        *slot = RailSet::idle_power(PowerSaving {
            method1: bits & 1 != 0,
            method2: bits & 2 != 0,
        })
        .milliwatts();
    }
    let kinds = batch.kinds();
    let savings = batch.savings();
    let timeouts = batch.timeouts();
    let mut total_mj = 0.0;
    let mut late = 0usize;
    for (i, gap) in gaps.iter().enumerate() {
        let g = gap.secs();
        let (cost_mj, busy) = match kinds[i] {
            KIND_IDLE => (idle_mw[savings[i] as usize] * g, latency),
            KIND_OFF => (e_buy_mj, busy_with_config),
            _ => {
                let p = idle_mw[savings[i] as usize];
                let t = timeouts[i].secs();
                if g <= t {
                    (p * g, latency)
                } else {
                    (p * t + e_buy_mj, t + busy_with_config)
                }
            }
        };
        total_mj += cost_mj;
        if busy > g {
            late += 1;
        }
    }
    AnalyticalEstimate {
        mean_gap_energy_mj: total_mj / gaps.len() as f64,
        late_rate: late as f64 / gaps.len() as f64,
    }
}

/// The energy half of [`analytical_replay`] alone — mean per-gap energy
/// in mJ (kept as the simple entry point for analyses that don't apply
/// a feasibility cap).
pub fn analytical_gap_score(
    model: &Analytical,
    spec: PolicySpec,
    params: &PolicyParams,
    gaps: &[Duration],
) -> f64 {
    analytical_replay(model, spec, params, gaps).mean_gap_energy_mj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::device::rails::PowerSaving;

    fn metrics(energy: f64, lifetime: f64, late: f64) -> EvalMetrics {
        EvalMetrics {
            energy_mj_per_item: energy,
            lifetime_h: lifetime,
            late_rate: late,
            items: 100,
        }
    }

    fn model() -> Analytical {
        let cfg = paper_default();
        Analytical::new(&cfg.item, cfg.workload.energy_budget)
    }

    #[test]
    fn objective_names_round_trip() {
        for kind in [ObjectiveKind::Energy, ObjectiveKind::Lifetime] {
            assert_eq!(ObjectiveKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ObjectiveKind::parse("Lifetime"), Some(ObjectiveKind::Lifetime));
        assert_eq!(ObjectiveKind::parse("watts"), None);
    }

    #[test]
    fn energy_score_is_the_per_item_energy() {
        let o = Objective::default();
        assert_eq!(o.score(&metrics(3.5, 10.0, 0.0)), 3.5);
    }

    #[test]
    fn lifetime_score_maximizes() {
        let o = Objective {
            kind: ObjectiveKind::Lifetime,
            max_late_rate: None,
        };
        assert!(o.score(&metrics(1.0, 50.0, 0.0)) < o.score(&metrics(1.0, 20.0, 0.0)));
    }

    #[test]
    fn late_rate_cap_makes_infeasible() {
        let o = Objective {
            kind: ObjectiveKind::Energy,
            max_late_rate: Some(0.05),
        };
        assert_eq!(o.score(&metrics(0.1, 10.0, 0.5)), f64::INFINITY);
        assert_eq!(o.score(&metrics(0.1, 10.0, 0.01)), 0.1);
        assert!(o.label().contains("late<=0.05"));
    }

    #[test]
    fn analytical_score_matches_closed_forms_on_static_policies() {
        let m = model();
        let gaps = vec![Duration::from_millis(40.0); 64];
        let params = PolicyParams::default();
        // always-off: every gap costs the buy price
        let onoff = analytical_gap_score(&m, PolicySpec::OnOff, &params, &gaps);
        let e_buy = (m.item.e_item_onoff() - m.item.e_active).millijoules();
        assert!((onoff - e_buy).abs() < 1e-12, "{onoff} vs {e_buy}");
        // always-idle at M1+2: every gap costs P_idle·gap
        let iw = analytical_gap_score(&m, PolicySpec::IdleWaitingM12, &params, &gaps);
        let expect = RailSet::idle_power(PowerSaving::M12).milliwatts() * 0.040;
        assert!((iw - expect).abs() < 1e-12, "{iw} vs {expect}");
        assert!(iw < onoff, "idling must win 40 ms gaps");
    }

    #[test]
    fn analytical_score_ranks_timeouts_correctly_on_long_gaps() {
        // 600 ms gaps sit beyond every crossover: a short timeout (buy
        // early) must beat a timeout longer than the gap (rent forever).
        let m = model();
        let gaps = vec![Duration::from_millis(600.0); 64];
        let short = PolicyParams {
            timeout: Some(Duration::from_millis(1.0)),
            ..PolicyParams::default()
        };
        let long = PolicyParams {
            timeout: Some(Duration::from_millis(5_000.0)),
            ..PolicyParams::default()
        };
        let s = analytical_gap_score(&m, PolicySpec::Timeout, &short, &gaps);
        let l = analytical_gap_score(&m, PolicySpec::Timeout, &long, &gaps);
        assert!(s < l, "short {s} vs long {l}");
    }

    #[test]
    fn analytical_score_is_stateful_for_predictors() {
        // A windowed-quantile candidate must be replayed with feedback:
        // on all-long gaps it should learn to power off (score near the
        // buy cost), not stay on its cold-start hedge.
        let m = model();
        let gaps = vec![Duration::from_millis(5_000.0); 64];
        let params = PolicyParams {
            window: 4,
            quantile: 0.5,
            ..PolicyParams::default()
        };
        let score = analytical_gap_score(&m, PolicySpec::WindowedQuantile, &params, &gaps);
        let always_idle =
            RailSet::idle_power(PowerSaving::M12).milliwatts() * 5.0 * 64.0 / 64.0;
        assert!(score < always_idle, "{score} must beat always-idle {always_idle}");
    }

    #[test]
    fn empty_gap_list_scores_zero() {
        let m = model();
        let est = analytical_replay(&m, PolicySpec::OnOff, &PolicyParams::default(), &[]);
        assert_eq!(est.mean_gap_energy_mj, 0.0);
        assert_eq!(est.late_rate, 0.0);
        assert_eq!(
            analytical_gap_score(&m, PolicySpec::OnOff, &PolicyParams::default(), &[]),
            0.0
        );
    }

    #[test]
    fn analytical_replay_estimates_lateness_and_the_cap_prunes_it() {
        // 10 ms gaps sit inside the 36.19 ms reconfiguration busy window:
        // always-off is analytically late on every gap, idling never is.
        let m = model();
        let gaps = vec![Duration::from_millis(10.0); 32];
        let params = PolicyParams::default();
        let off = analytical_replay(&m, PolicySpec::OnOff, &params, &gaps);
        assert!((off.late_rate - 1.0).abs() < 1e-12, "{}", off.late_rate);
        let idle = analytical_replay(&m, PolicySpec::IdleWaitingM12, &params, &gaps);
        assert_eq!(idle.late_rate, 0.0);
        // a capped objective marks the infeasible estimate +inf in the
        // pre-filter, exactly like Objective::score does for DES metrics
        let capped = Objective {
            kind: ObjectiveKind::Energy,
            max_late_rate: Some(0.05),
        };
        assert_eq!(capped.prefilter_score(&off), f64::INFINITY);
        assert!(capped.prefilter_score(&idle).is_finite());
        // uncapped, the pre-filter ranks purely by energy
        let free = Objective::default();
        assert_eq!(free.prefilter_score(&off), off.mean_gap_energy_mj);
    }
}
