//! Emitting tuned parameters in consumable forms — and loading them back.
//!
//! Three surfaces, all round-trippable:
//!
//! * [`flags_line`] — a CLI fragment `repro serve` (and `repro exp4`
//!   via config overlay) accepts verbatim, e.g.
//!   `--policy windowed-quantile --saving m12 --window 24 --quantile 0.35`.
//! * [`yaml_fragment`] — a `policy`/`policy_params` YAML block that can
//!   be pasted into (or included as) a config file.
//! * [`load_fragment`] — parses a written fragment back into
//!   `(PolicySpec, PolicyParams)`; `repro multi --slot-a-params /
//!   --slot-b-params` uses it to run a tuned heterogeneous fleet.
//!
//! Only the knobs that the policy actually reads are emitted (per
//! [`ParamSpace::for_spec`]), so a fragment documents the deployment
//! rather than echoing the whole tunable table.

use crate::config::schema::{PolicyParams, PolicySpec};
use crate::device::rails::PowerSaving;
use crate::tuner::space::{Knob, ParamSpace};

/// The config/CLI name of a power-saving level (the inverse of
/// [`parse_saving`](crate::config::schema::parse_saving)). The
/// never-constructed method-2-only combination maps to `baseline`
/// defensively.
pub fn saving_name(s: PowerSaving) -> &'static str {
    match (s.method1, s.method2) {
        (true, true) => "m12",
        (true, false) => "m1",
        (false, _) => "baseline",
    }
}

/// The `(flag, value)` pairs for the knobs `spec` actually reads.
fn knob_pairs(spec: PolicySpec, params: &PolicyParams) -> Vec<(&'static str, String)> {
    let space = ParamSpace::for_spec(spec);
    let mut out = Vec::new();
    if !space.savings.is_empty() {
        out.push(("saving", saving_name(params.saving).to_string()));
    }
    for knob in &space.knobs {
        match knob.name {
            Knob::TIMEOUT_MS => {
                if let Some(t) = params.timeout {
                    out.push(("timeout-ms", format!("{}", t.millis())));
                }
            }
            Knob::EMA_ALPHA => out.push(("ema-alpha", format!("{}", params.ema_alpha))),
            Knob::WINDOW => out.push(("window", params.window.to_string())),
            Knob::QUANTILE => out.push(("quantile", format!("{}", params.quantile))),
            Knob::COMPONENTS => out.push(("components", params.components.to_string())),
            _ => {}
        }
    }
    // the bandit's trained action table is not a searched knob, but a
    // trained deployment artifact (`repro train --emit`) — emit it so the
    // fragment reconstructs the deployed policy exactly. The value is 64
    // letters from {i, o, t}, which the mini-YAML scalar parser can never
    // mistake for a number.
    if spec == PolicySpec::BanditPolicy {
        if let Some(table) = &params.table {
            out.push(("table", table.render()));
        }
    }
    out
}

/// A flags line `repro serve` accepts verbatim:
/// `--policy <spec> [--saving <level>] [--<knob> <value>]…`.
pub fn flags_line(spec: PolicySpec, params: &PolicyParams) -> String {
    let mut out = format!("--policy {}", spec.name());
    for (flag, value) in knob_pairs(spec, params) {
        out.push_str(&format!(" --{flag} {value}"));
    }
    out
}

/// A compact human label (`saving=m12 window=24 quantile=0.35`) for
/// tables and reports.
pub fn params_label(spec: PolicySpec, params: &PolicyParams) -> String {
    let pairs = knob_pairs(spec, params);
    if pairs.is_empty() {
        return "(no tunables)".to_string();
    }
    pairs
        .iter()
        .map(|(flag, value)| format!("{}={value}", flag.replace('-', "_")))
        .collect::<Vec<_>>()
        .join(" ")
}

/// A `policy:` + `policy_params:` YAML block that config files (and
/// [`load_fragment`]) consume directly.
pub fn yaml_fragment(spec: PolicySpec, params: &PolicyParams) -> String {
    let mut out = format!("policy: {}\n", spec.name());
    let pairs = knob_pairs(spec, params);
    if !pairs.is_empty() {
        out.push_str("policy_params:\n");
        for (flag, value) in pairs {
            out.push_str(&format!("  {}: {value}\n", flag.replace('-', "_")));
        }
    }
    out
}

/// Why a tuned-params fragment failed to load.
#[derive(Debug, thiserror::Error)]
pub enum FragmentError {
    /// The file could not be read.
    #[error("reading tuned params {path}: {source}")]
    Io {
        /// The offending path.
        path: String,
        /// The underlying IO error.
        #[source]
        source: std::io::Error,
    },
    /// The file is not parseable YAML/JSON.
    #[error("parsing tuned params {path}: {msg}")]
    Parse {
        /// The offending path.
        path: String,
        /// Parser diagnostics.
        msg: String,
    },
    /// The document is parseable but not a valid fragment.
    #[error("tuned params {path}: {msg}")]
    Invalid {
        /// The offending path.
        path: String,
        /// What is wrong and how to fix it.
        msg: String,
    },
}

/// Load a `policy` + `policy_params` fragment (as written by
/// [`yaml_fragment`] / `repro tune --emit`), range-checking the params
/// exactly like the config loader does.
pub fn load_fragment(
    path: impl AsRef<std::path::Path>,
) -> Result<(PolicySpec, PolicyParams), FragmentError> {
    let path = path.as_ref();
    let display = path.display().to_string();
    let text = std::fs::read_to_string(path).map_err(|source| FragmentError::Io {
        path: display.clone(),
        source,
    })?;
    let root = crate::config::loader::parse_str(&text).map_err(|e| FragmentError::Parse {
        path: display.clone(),
        msg: e.to_string(),
    })?;
    let name = root
        .get("policy")
        .and_then(|v| v.as_str())
        .ok_or_else(|| FragmentError::Invalid {
            path: display.clone(),
            msg: "missing 'policy: <name>' key".to_string(),
        })?;
    let spec = PolicySpec::parse(name).ok_or_else(|| FragmentError::Invalid {
        path: display.clone(),
        msg: format!(
            "unknown policy '{name}' (expected one of: {})",
            PolicySpec::ALL.map(|s| s.name()).join(", ")
        ),
    })?;
    let params = match root.get("policy_params") {
        None => PolicyParams::default(),
        Some(p) => PolicyParams::from_json(p, "policy_params").map_err(|e| {
            FragmentError::Invalid {
                path: display.clone(),
                msg: e.to_string(),
            }
        })?,
    };
    params.validate().map_err(|msg| FragmentError::Invalid {
        path: display,
        msg,
    })?;
    Ok((spec, params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::parse_saving;
    use crate::util::units::Duration;

    fn tuned() -> PolicyParams {
        PolicyParams {
            saving: PowerSaving::M12,
            window: 24,
            quantile: 0.35,
            ..PolicyParams::default()
        }
    }

    #[test]
    fn saving_names_invert_parse_saving() {
        for s in [PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12] {
            assert_eq!(parse_saving(saving_name(s)), Some(s));
        }
    }

    #[test]
    fn flags_line_emits_only_relevant_knobs() {
        let line = flags_line(PolicySpec::WindowedQuantile, &tuned());
        assert_eq!(
            line,
            "--policy windowed-quantile --saving m12 --window 24 --quantile 0.35"
        );
        // a timeout policy emits no quantile/window noise
        let p = PolicyParams {
            timeout: Some(Duration::from_millis(87.5)),
            ..PolicyParams::default()
        };
        let line = flags_line(PolicySpec::Timeout, &p);
        assert_eq!(line, "--policy timeout --saving m12 --timeout-ms 87.5");
        // static policies carry no tunables at all
        assert_eq!(flags_line(PolicySpec::OnOff, &tuned()), "--policy on-off");
        assert_eq!(params_label(PolicySpec::OnOff, &tuned()), "(no tunables)");
    }

    #[test]
    fn yaml_fragment_round_trips_through_load_fragment() {
        let dir = std::env::temp_dir().join("idlewait_tuner_emit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("best.yaml");
        let doc = yaml_fragment(PolicySpec::WindowedQuantile, &tuned());
        std::fs::write(&path, &doc).unwrap();
        let (spec, params) = load_fragment(&path).unwrap();
        assert_eq!(spec, PolicySpec::WindowedQuantile);
        assert_eq!(params.saving, PowerSaving::M12);
        assert_eq!(params.window, 24);
        assert!((params.quantile - 0.35).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bandit_table_round_trips_through_the_fragment() {
        use crate::config::schema::PolicyTable;
        let mut table = PolicyTable::hedge();
        table.0[0] = b'i';
        table.0[63] = b'o';
        let params = PolicyParams {
            saving: PowerSaving::M12,
            ema_alpha: 0.25,
            table: Some(table),
            ..PolicyParams::default()
        };
        let line = flags_line(PolicySpec::BanditPolicy, &params);
        assert!(line.starts_with("--policy bandit --saving m12 --ema-alpha 0.25 --table i"));
        let dir = std::env::temp_dir().join("idlewait_tuner_emit_table");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trained.yaml");
        std::fs::write(&path, yaml_fragment(PolicySpec::BanditPolicy, &params)).unwrap();
        let (spec, loaded) = load_fragment(&path).unwrap();
        assert_eq!(spec, PolicySpec::BanditPolicy);
        assert_eq!(loaded.table, Some(table));
        assert!((loaded.ema_alpha - 0.25).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bayes_fragment_emits_components() {
        let params = PolicyParams {
            components: 4,
            ..PolicyParams::default()
        };
        assert_eq!(
            flags_line(PolicySpec::BayesMixture, &params),
            "--policy bayes-mixture --saving m12 --components 4"
        );
        assert_eq!(
            params_label(PolicySpec::BayesMixture, &params),
            "saving=m12 components=4"
        );
    }

    #[test]
    fn load_fragment_errors_name_the_path() {
        let err = load_fragment("/nonexistent/best.yaml").unwrap_err();
        assert!(err.to_string().contains("/nonexistent/best.yaml"), "{err}");

        let dir = std::env::temp_dir().join("idlewait_tuner_emit_bad");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, content, want) in [
            ("no_policy.yaml", "policy_params:\n  window: 8\n", "missing 'policy"),
            ("bad_policy.yaml", "policy: warp-drive\n", "unknown policy"),
            (
                "bad_params.yaml",
                "policy: windowed-quantile\npolicy_params:\n  quantile: 7\n",
                "quantile",
            ),
        ] {
            let path = dir.join(name);
            std::fs::write(&path, content).unwrap();
            let err = load_fragment(&path).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(want), "{name}: {msg}");
            assert!(msg.contains(name), "{name}: error must name the file: {msg}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fragment_without_params_block_uses_defaults() {
        let dir = std::env::temp_dir().join("idlewait_tuner_emit_min");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("min.yaml");
        std::fs::write(&path, "policy: on-off\n").unwrap();
        let (spec, params) = load_fragment(&path).unwrap();
        assert_eq!(spec, PolicySpec::OnOff);
        assert_eq!(params, PolicyParams::default());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
