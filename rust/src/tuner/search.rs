//! Search strategies over a [`ParamSpace`](super::space::ParamSpace):
//! exhaustive grid, seeded random sampling, and successive halving.
//!
//! Strategies only decide *which candidates to evaluate on how many
//! gaps*; evaluation itself runs on the shared
//! [`SweepRunner`](crate::runner::SweepRunner) in
//! [`tune`](super::tune::tune), so the whole search inherits the sweep
//! engine's any-thread-count determinism.

/// Which search the tuner runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchStrategy {
    /// Full-factorial enumeration of the space's grid levels, every
    /// candidate scored on the full training split. Exhaustive but
    /// bounded by `budget` via the analytical pre-filter.
    Grid,
    /// `budget` scale-uniform random points (oversampled 4×, pre-filtered
    /// analytically down to `budget`), every survivor scored on the full
    /// training split. The DPUConfig-style default for spaces where grid
    /// resolution wastes evaluations.
    Random,
    /// Successive halving: start from the random pool, score every
    /// survivor on a short prefix of the training split, keep the best
    /// half, double the prefix, repeat until the full split. Spends most
    /// DES time on promising candidates.
    Halving,
}

impl SearchStrategy {
    /// Parse a CLI search name.
    pub fn parse(s: &str) -> Option<SearchStrategy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "grid" => Some(SearchStrategy::Grid),
            "random" | "rand" => Some(SearchStrategy::Random),
            "halving" | "successive-halving" | "sha" => Some(SearchStrategy::Halving),
            _ => None,
        }
    }

    /// Canonical name (CSV/report surface).
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Grid => "grid",
            SearchStrategy::Random => "random",
            SearchStrategy::Halving => "halving",
        }
    }

    /// All strategies, for error messages and docs.
    pub const ALL: [SearchStrategy; 3] = [
        SearchStrategy::Grid,
        SearchStrategy::Random,
        SearchStrategy::Halving,
    ];
}

impl std::fmt::Display for SearchStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for s in SearchStrategy::ALL {
            assert_eq!(SearchStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(
            SearchStrategy::parse("successive-halving"),
            Some(SearchStrategy::Halving)
        );
        assert_eq!(SearchStrategy::parse("simulated-annealing"), None);
    }
}
