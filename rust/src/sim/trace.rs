//! Simulation tracing: a bounded log of labelled spans used for debugging
//! simulations and for the validation experiment's detailed output.
//!
//! A [`Trace`] records `(t_start, t_end, label)` spans (e.g. one span per
//! FPGA phase). It is bounded: when full it stops recording but keeps
//! counting, so long lifetime runs don't accumulate gigabytes of spans.

use std::collections::BTreeMap;

use crate::sim::time::SimTime;
use crate::util::units::Duration;

/// A labelled time span in the simulation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Span start time.
    pub start: SimTime,
    /// Span end time.
    pub end: SimTime,
    /// What the span covers (phase name).
    pub label: &'static str,
}

impl Span {
    /// The span's length.
    pub fn duration(&self) -> Duration {
        self.end.since(self.start)
    }
}

/// Bounded span recorder with per-label aggregate durations.
#[derive(Debug, Clone)]
pub struct Trace {
    spans: Vec<Span>,
    capacity: usize,
    dropped: u64,
    totals: BTreeMap<&'static str, (u64, Duration)>,
}

impl Trace {
    /// A recorder keeping at most `capacity` individual spans.
    pub fn new(capacity: usize) -> Trace {
        Trace {
            spans: Vec::new(),
            capacity,
            dropped: 0,
            totals: BTreeMap::new(),
        }
    }

    /// A trace that only aggregates (records no individual spans).
    pub fn aggregate_only() -> Trace {
        Trace::new(0)
    }

    /// Record one span (aggregates always; stores while under capacity).
    pub fn record(&mut self, start: SimTime, end: SimTime, label: &'static str) {
        debug_assert!(end >= start, "span ends before it starts");
        let entry = self.totals.entry(label).or_insert((0, Duration::ZERO));
        entry.0 += 1;
        entry.1 += end.since(start);
        if self.spans.len() < self.capacity {
            self.spans.push(Span { start, end, label });
        } else {
            self.dropped += 1;
        }
    }

    /// The recorded spans (up to capacity).
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Spans dropped after capacity was reached.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Number of spans recorded for a label (including dropped ones).
    pub fn count(&self, label: &str) -> u64 {
        self.totals.get(label).map(|(n, _)| *n).unwrap_or(0)
    }

    /// Total duration across all spans with this label.
    pub fn total_duration(&self, label: &str) -> Duration {
        self.totals
            .get(label)
            .map(|(_, d)| *d)
            .unwrap_or(Duration::ZERO)
    }

    /// All labels seen, in sorted order.
    pub fn labels(&self) -> Vec<&'static str> {
        self.totals.keys().copied().collect()
    }

    /// Render an aggregate summary table (label, count, total ms).
    pub fn summary(&self) -> String {
        use crate::util::table::{fnum, Table};
        let mut t = Table::new(&["phase", "count", "total_ms"]);
        for (label, (count, dur)) in &self.totals {
            t.row(&[label.to_string(), count.to_string(), fnum(dur.millis(), 4)]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ns: u64) -> SimTime {
        SimTime::from_nanos(ns)
    }

    #[test]
    fn records_and_aggregates() {
        let mut tr = Trace::new(10);
        tr.record(t(0), t(100), "config");
        tr.record(t(100), t(150), "inference");
        tr.record(t(150), t(250), "config");
        assert_eq!(tr.spans().len(), 3);
        assert_eq!(tr.count("config"), 2);
        assert!((tr.total_duration("config").secs() - 200e-9).abs() < 1e-18);
        assert_eq!(tr.count("missing"), 0);
    }

    #[test]
    fn bounded_capacity_keeps_counting() {
        let mut tr = Trace::new(2);
        for i in 0..5 {
            tr.record(t(i * 10), t(i * 10 + 5), "x");
        }
        assert_eq!(tr.spans().len(), 2);
        assert_eq!(tr.dropped(), 3);
        assert_eq!(tr.count("x"), 5);
    }

    #[test]
    fn aggregate_only_records_nothing() {
        let mut tr = Trace::aggregate_only();
        tr.record(t(0), t(10), "y");
        assert!(tr.spans().is_empty());
        assert_eq!(tr.count("y"), 1);
    }

    #[test]
    fn summary_renders_labels() {
        let mut tr = Trace::new(4);
        tr.record(t(0), t(36_145_000), "configuration");
        let s = tr.summary();
        assert!(s.contains("configuration"));
        assert!(s.contains("36.145"));
    }

    #[test]
    fn labels_sorted() {
        let mut tr = Trace::new(4);
        tr.record(t(0), t(1), "b");
        tr.record(t(1), t(2), "a");
        assert_eq!(tr.labels(), vec!["a", "b"]);
    }
}
