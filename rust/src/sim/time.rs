//! Simulated time: an integer nanosecond timestamp.
//!
//! The paper's phase durations span five orders of magnitude (2 µs data
//! offload → 1.5 s worst-case configuration → multi-hour lifetimes), so
//! float timestamps would accumulate error over the millions of events in
//! a lifetime simulation. `SimTime` is a `u64` count of nanoseconds since
//! simulation start: exact addition, total ordering, ~584 years of range.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

use crate::util::units::Duration;

/// Absolute simulated time (nanoseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(u64);

impl SimTime {
    /// Time zero (simulation start).
    pub const ZERO: SimTime = SimTime(0);
    /// The maximum representable instant.
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// An instant from integer nanoseconds since start.
    #[inline]
    pub fn from_nanos(ns: u64) -> SimTime {
        SimTime(ns)
    }

    /// Nanoseconds since simulation start.
    #[inline]
    pub fn nanos(self) -> u64 {
        self.0
    }

    /// This instant as a duration since time zero.
    #[inline]
    pub fn as_duration(self) -> Duration {
        Duration::from_nanos(self.0 as f64)
    }

    /// Elapsed duration since `earlier`. Panics in debug if negative.
    #[inline]
    pub fn since(self, earlier: SimTime) -> Duration {
        debug_assert!(self >= earlier, "since() would be negative");
        Duration::from_nanos((self.0 - earlier.0) as f64)
    }

    /// `self - other`, clamped at zero instead of underflowing.
    #[inline]
    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

/// Convert a physical duration to integer nanoseconds (round-to-nearest).
#[inline]
pub fn dur_to_nanos(d: Duration) -> u64 {
    let ns = d.secs() * 1e9;
    debug_assert!(ns >= 0.0 && ns.is_finite(), "bad duration {ns}");
    ns.round() as u64
}

impl Add<Duration> for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: Duration) -> SimTime {
        SimTime(self.0 + dur_to_nanos(rhs))
    }
}

impl AddAssign<Duration> for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += dur_to_nanos(rhs);
    }
}

impl Sub<SimTime> for SimTime {
    type Output = Duration;
    #[inline]
    fn sub(self, rhs: SimTime) -> Duration {
        self.since(rhs)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ms = self.0 as f64 / 1e6;
        write!(f, "t={ms:.6}ms")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_duration_is_exact() {
        let t = SimTime::ZERO + Duration::from_millis(36.145);
        assert_eq!(t.nanos(), 36_145_000);
    }

    #[test]
    fn accumulation_over_many_periods_is_exact() {
        // One million 40 ms periods: float accumulation would drift; u64
        // nanoseconds must be exact.
        let mut t = SimTime::ZERO;
        let period = Duration::from_millis(40.0);
        for _ in 0..1_000_000 {
            t += period;
        }
        assert_eq!(t.nanos(), 40_000_000 * 1_000_000u64);
    }

    #[test]
    fn since_and_sub() {
        let a = SimTime::from_nanos(1_000_000);
        let b = SimTime::from_nanos(3_500_000);
        assert!((b.since(a).millis() - 2.5).abs() < 1e-12);
        assert!(((b - a).millis() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ordering_is_total() {
        let a = SimTime::from_nanos(10);
        let b = SimTime::from_nanos(20);
        assert!(a < b);
        assert_eq!(a.max(b), b);
    }

    #[test]
    fn rounding_of_sub_nanosecond() {
        // 0.0002 ms = 200 ns exactly; 0.00005 ms = 50 ns
        assert_eq!(dur_to_nanos(Duration::from_millis(0.0002)), 200);
        assert_eq!(dur_to_nanos(Duration::from_millis(0.00005)), 50);
    }

    #[test]
    fn display_formats_ms() {
        let t = SimTime::from_nanos(36_145_000);
        assert_eq!(format!("{t}"), "t=36.145000ms");
    }

    #[test]
    fn saturating_sub_floors_at_zero() {
        let a = SimTime::from_nanos(5);
        let b = SimTime::from_nanos(10);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }
}
