//! The discrete-event simulation engine.
//!
//! Generic over the event type `E` and a state `S`. The engine owns the
//! clock and the queue; handlers receive a [`Ctx`] through which they can
//! read the current time and schedule follow-up events. This split (state
//! separate from scheduler) keeps handler borrows simple and makes the
//! platform simulation in `strategies::simulate` a plain `match` over an
//! event enum.

use crate::sim::event::EventQueue;
use crate::sim::time::SimTime;
use crate::util::units::Duration;

/// Scheduling context passed to event handlers.
pub struct Ctx<E> {
    now: SimTime,
    queue: EventQueue<E>,
    stopped: bool,
    fired: u64,
}

impl<E> Ctx<E> {
    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule an event after a relative delay.
    #[inline]
    pub fn schedule_in(&mut self, delay: Duration, event: E) {
        self.queue.schedule(self.now + delay, event);
    }

    /// Schedule an event at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.queue.schedule(at, event);
    }

    /// Request the run loop to stop after the current handler returns.
    pub fn stop(&mut self) {
        self.stopped = true;
    }

    /// Number of events fired so far.
    pub fn events_fired(&self) -> u64 {
        self.fired
    }
}

/// Outcome of a simulation run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    /// Total events processed.
    pub events: u64,
    /// Final simulated time.
    pub end_time: SimTime,
    /// True if a handler called `stop()` (vs the queue draining).
    pub stopped_early: bool,
}

/// The engine: event queue + clock + run loop.
pub struct Engine<E> {
    ctx: Ctx<E>,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    /// An engine with an empty event queue at time zero.
    pub fn new() -> Self {
        Engine {
            ctx: Ctx {
                now: SimTime::ZERO,
                queue: EventQueue::new(),
                stopped: false,
                fired: 0,
            },
        }
    }

    /// Seed the initial event(s) before running.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        self.ctx.queue.schedule(at, event);
    }

    /// Schedule `event` at `delay` after the current clock.
    pub fn schedule_in(&mut self, delay: Duration, event: E) {
        let at = self.ctx.now + delay;
        self.ctx.queue.schedule(at, event);
    }

    /// Run until the queue drains, a handler stops the run, or `max_events`
    /// is hit (guard against runaway self-scheduling loops).
    pub fn run<S>(
        &mut self,
        state: &mut S,
        max_events: u64,
        mut handler: impl FnMut(&mut Ctx<E>, &mut S, E),
    ) -> RunStats {
        let ctx = &mut self.ctx;
        while !ctx.stopped {
            let Some((at, event)) = ctx.queue.pop() else {
                break;
            };
            debug_assert!(at >= ctx.now, "time went backwards");
            ctx.now = at;
            ctx.fired += 1;
            handler(ctx, state, event);
            if ctx.fired >= max_events {
                break;
            }
        }
        RunStats {
            events: ctx.fired,
            end_time: ctx.now,
            stopped_early: ctx.stopped,
        }
    }

    /// The current simulation clock.
    pub fn now(&self) -> SimTime {
        self.ctx.now
    }

    /// Return the engine to its just-constructed state (clock zero, empty
    /// queue, counters cleared) while keeping the queue's backing
    /// allocation — the sweep-cell reuse path. A reset engine runs
    /// exactly like a fresh [`Engine::new`].
    pub fn reset(&mut self) {
        self.ctx.now = SimTime::ZERO;
        self.ctx.stopped = false;
        self.ctx.fired = 0;
        self.ctx.queue.reset();
    }

    /// Clear a handler's `stop()` request so a subsequent [`Engine::run`]
    /// continues from the current clock and queue — the resumable-
    /// simulation path (the tuner carries train-prefix state across
    /// successive-halving rungs through this). Unlike [`Engine::reset`],
    /// the clock, the queue and the fired-event counter are all kept.
    pub fn resume(&mut self) {
        self.ctx.stopped = false;
    }

    /// True once a handler has requested a stop (and no `resume`/`reset`
    /// has cleared it).
    pub fn is_stopped(&self) -> bool {
        self.ctx.stopped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        Tick(u32),
        Stop,
    }

    #[test]
    fn self_scheduling_ticks() {
        let mut engine = Engine::new();
        engine.schedule_at(SimTime::ZERO, Ev::Tick(0));
        let mut seen = Vec::new();
        let stats = engine.run(&mut seen, u64::MAX, |ctx, seen, ev| match ev {
            Ev::Tick(n) => {
                seen.push((ctx.now().nanos(), n));
                if n < 4 {
                    ctx.schedule_in(Duration::from_millis(40.0), Ev::Tick(n + 1));
                }
            }
            Ev::Stop => ctx.stop(),
        });
        assert_eq!(stats.events, 5);
        assert!(!stats.stopped_early);
        assert_eq!(
            seen,
            vec![
                (0, 0),
                (40_000_000, 1),
                (80_000_000, 2),
                (120_000_000, 3),
                (160_000_000, 4)
            ]
        );
        assert_eq!(stats.end_time.nanos(), 160_000_000);
    }

    #[test]
    fn stop_aborts_remaining_events() {
        let mut engine = Engine::new();
        engine.schedule_at(SimTime::from_nanos(1), Ev::Stop);
        engine.schedule_at(SimTime::from_nanos(2), Ev::Tick(99));
        let mut seen: Vec<(u64, u32)> = Vec::new();
        let stats = engine.run(&mut seen, u64::MAX, |ctx, seen, ev| match ev {
            Ev::Tick(n) => seen.push((ctx.now().nanos(), n)),
            Ev::Stop => ctx.stop(),
        });
        assert!(stats.stopped_early);
        assert_eq!(stats.events, 1);
        assert!(seen.is_empty());
    }

    #[test]
    fn max_events_guard() {
        let mut engine = Engine::new();
        engine.schedule_at(SimTime::ZERO, Ev::Tick(0));
        let mut count = 0u64;
        let stats = engine.run(&mut count, 1000, |ctx, count, ev| {
            if let Ev::Tick(_) = ev {
                *count += 1;
                ctx.schedule_in(Duration::from_nanos(1.0), Ev::Tick(0));
            }
        });
        assert_eq!(stats.events, 1000);
        assert_eq!(count, 1000);
    }

    #[test]
    fn reset_engine_replays_like_a_fresh_one() {
        let mut engine = Engine::new();
        let run = |engine: &mut Engine<Ev>| {
            engine.schedule_at(SimTime::ZERO, Ev::Tick(0));
            let mut seen = Vec::new();
            let stats = engine.run(&mut seen, u64::MAX, |ctx, seen, ev| {
                if let Ev::Tick(n) = ev {
                    seen.push((ctx.now().nanos(), n));
                    if n < 3 {
                        ctx.schedule_in(Duration::from_millis(10.0), Ev::Tick(n + 1));
                    }
                }
            });
            (seen, stats.events, stats.end_time)
        };
        let first = run(&mut engine);
        engine.reset();
        assert_eq!(engine.now(), SimTime::ZERO);
        let second = run(&mut engine);
        assert_eq!(first, second);
    }

    #[test]
    fn resume_continues_after_a_stop() {
        let mut engine = Engine::new();
        engine.schedule_at(SimTime::from_nanos(1), Ev::Stop);
        engine.schedule_at(SimTime::from_nanos(2), Ev::Tick(7));
        let mut seen: Vec<(u64, u32)> = Vec::new();
        let mut handler = |ctx: &mut Ctx<Ev>, seen: &mut Vec<(u64, u32)>, ev: Ev| match ev {
            Ev::Tick(n) => seen.push((ctx.now().nanos(), n)),
            Ev::Stop => ctx.stop(),
        };
        let stats = engine.run(&mut seen, u64::MAX, &mut handler);
        assert!(stats.stopped_early && engine.is_stopped());
        assert!(seen.is_empty());
        // resume keeps the clock, the queue and the event counter
        engine.resume();
        assert!(!engine.is_stopped());
        let stats = engine.run(&mut seen, u64::MAX, &mut handler);
        assert_eq!(seen, vec![(2, 7)]);
        assert_eq!(stats.events, 2);
    }

    #[test]
    fn events_at_same_time_run_in_schedule_order() {
        let mut engine = Engine::new();
        engine.schedule_at(SimTime::ZERO, Ev::Tick(1));
        engine.schedule_at(SimTime::ZERO, Ev::Tick(2));
        engine.schedule_at(SimTime::ZERO, Ev::Tick(3));
        let mut order = Vec::new();
        engine.run(&mut order, u64::MAX, |_, order, ev| {
            if let Ev::Tick(n) = ev {
                order.push(n)
            }
        });
        assert_eq!(order, vec![1, 2, 3]);
    }
}
