//! Discrete-event simulation core: integer-nanosecond clock, deterministic
//! event queue, generic engine and bounded tracing.
//!
//! The platform simulation (`strategies::simulate`) and the serving
//! coordinator both run on this engine; determinism (total event order)
//! is what lets the validation experiment compare DES results against the
//! analytical model to sub-percent precision.

pub mod engine;
pub mod event;
pub mod time;
pub mod trace;

pub use engine::{Ctx, Engine, RunStats};
pub use event::EventQueue;
pub use time::{dur_to_nanos, SimTime};
pub use trace::{Span, Trace};
