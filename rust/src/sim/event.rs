//! Event queue for the discrete-event simulator.
//!
//! A binary min-heap ordered by `(time, seq)`: `seq` is a monotonically
//! increasing tie-breaker so that events scheduled earlier fire earlier at
//! equal timestamps — this makes every simulation run deterministic, which
//! the validation experiment (DES vs analytical model) depends on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sim::time::SimTime;

#[derive(Debug)]
struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

// Manual ord impls: BinaryHeap is a max-heap, so invert the comparison.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smallest (at, seq) = greatest priority
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }

    /// An empty queue with pre-allocated capacity.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            next_seq: 0,
        }
    }

    /// Schedule `event` at absolute time `at`.
    pub fn schedule(&mut self, at: SimTime, event: E) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { at, seq, event });
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| (e.at, e.event))
    }

    /// Earliest scheduled timestamp without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    /// Events currently queued.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all queued events.
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Return the queue to its just-constructed state — empty, sequence
    /// counter at zero — while keeping the heap's backing allocation.
    /// This is the sweep-cell reuse path: rebuilding a queue per DES run
    /// re-allocated the heap every cell; a reset queue produces the
    /// identical `(time, seq)` order a fresh one would.
    pub fn reset(&mut self) {
        self.heap.clear();
        self.next_seq = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Duration;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_nanos(30), "c");
        q.schedule(SimTime::from_nanos(10), "a");
        q.schedule(SimTime::from_nanos(20), "b");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_fire_in_schedule_order() {
        let mut q = EventQueue::new();
        let t = SimTime::from_nanos(5);
        for i in 0..100 {
            q.schedule(t, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_nanos(10), 1);
        q.schedule(SimTime::from_nanos(30), 3);
        assert_eq!(q.pop().unwrap().1, 1);
        q.schedule(SimTime::from_nanos(20), 2);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::ZERO + Duration::from_millis(1.0), ());
        assert_eq!(q.peek_time(), Some(SimTime::from_nanos(1_000_000)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn clear_empties() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::ZERO, ());
        q.clear();
        assert!(q.is_empty());
    }

    #[test]
    fn reset_restarts_the_sequence_counter() {
        let mut q = EventQueue::new();
        let t = SimTime::from_nanos(5);
        for i in 0..10 {
            q.schedule(t, i);
        }
        q.pop();
        q.reset();
        assert!(q.is_empty());
        // a reset queue breaks same-time ties exactly like a fresh one
        q.schedule(t, 100);
        q.schedule(t, 200);
        assert_eq!(q.pop().unwrap().1, 100);
        assert_eq!(q.pop().unwrap().1, 200);
    }
}
