//! Parameter grids and sweep cells.
//!
//! A [`Grid`] is an ordered list of parameter points; a [`Cell`] is one
//! point paired with its index and a deterministically-derived PRNG seed.
//! Grids replicate the experiments' original loop semantics exactly —
//! [`Grid::stepped`] accumulates `t += step` with the same `+ 1e-9`
//! inclusive tolerance the old `while` loops used, so migrated sweeps
//! produce bit-identical floating-point sample positions.

use crate::util::rng::{SplitMix64, Xoshiro256ss};

/// An ordered set of parameter points to sweep over.
#[derive(Debug, Clone)]
pub struct Grid<P> {
    points: Vec<P>,
}

impl<P> Grid<P> {
    /// A grid over an explicit list of points.
    pub fn new(points: Vec<P>) -> Grid<P> {
        Grid { points }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when the grid has no points.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The points, in sweep order.
    pub fn points(&self) -> &[P] {
        &self.points
    }

    /// Consume the grid, yielding its points.
    pub fn into_points(self) -> Vec<P> {
        self.points
    }
}

impl Grid<f64> {
    /// Inclusive stepped range `min, min+step, …` up to `max` (with the
    /// experiments' historical `1e-9` end tolerance). Accumulates rather
    /// than multiplying so sample positions match the pre-runner loops
    /// bit-for-bit.
    pub fn stepped(min: f64, max: f64, step: f64) -> Grid<f64> {
        assert!(step > 0.0, "grid step must be positive");
        let mut points = Vec::new();
        let mut t = min;
        while t <= max + 1e-9 {
            points.push(t);
            t += step;
        }
        Grid { points }
    }
}

/// Cartesian product of two axes, row-major (`a` outer, `b` inner).
pub fn cross<A: Clone, B: Clone>(a: &[A], b: &[B]) -> Grid<(A, B)> {
    let mut points = Vec::with_capacity(a.len() * b.len());
    for x in a {
        for y in b {
            points.push((x.clone(), y.clone()));
        }
    }
    Grid::new(points)
}

/// One unit of sweep work: the parameter point, its position in the grid
/// and a per-cell seed for any stochastic work inside the cell.
#[derive(Debug)]
pub struct Cell<'a, P> {
    /// Position of this point in the grid (stable across thread counts).
    pub index: usize,
    /// The parameter point.
    pub params: &'a P,
    /// Seed derived from `(sweep base seed, index)` only — independent of
    /// thread count and scheduling order.
    pub seed: u64,
}

impl<P> Cell<'_, P> {
    /// A fresh deterministic PRNG stream for this cell.
    pub fn rng(&self) -> Xoshiro256ss {
        Xoshiro256ss::new(self.seed)
    }
}

/// Derive a cell seed from the sweep's base seed and the cell index.
///
/// SplitMix64 over the mixed pair gives well-separated streams even for
/// adjacent indices (the xoshiro authors' recommended seeding path).
pub fn derive_seed(base: u64, index: u64) -> u64 {
    let mut sm = SplitMix64::new(base ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sm.next()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stepped_matches_legacy_loop() {
        // exp2's loop shape: 10..=120 at 0.01 → 11,001 points
        let g = Grid::stepped(10.0, 120.0, 0.01);
        assert_eq!(g.len(), 11_001);
        assert_eq!(g.points()[0], 10.0);
        // the last point must equal the accumulated value, not 120.0 exactly
        let mut t = 10.0;
        while t <= 120.0 + 1e-9 {
            t += 0.01;
        }
        let expected_last = t - 0.01;
        assert_eq!(*g.points().last().unwrap(), expected_last);
    }

    #[test]
    fn stepped_accumulates_identically() {
        let g = Grid::stepped(10.0, 120.0, 1.0);
        let mut reference = Vec::new();
        let mut t = 10.0;
        while t <= 120.0 + 1e-9 {
            reference.push(t);
            t += 1.0;
        }
        assert_eq!(g.points(), reference.as_slice());
    }

    #[test]
    fn cross_is_row_major() {
        let g = cross(&[1, 2], &["a", "b", "c"]);
        assert_eq!(g.len(), 6);
        assert_eq!(g.points()[0], (1, "a"));
        assert_eq!(g.points()[2], (1, "c"));
        assert_eq!(g.points()[3], (2, "a"));
    }

    #[test]
    fn derived_seeds_are_stable_and_distinct() {
        let a = derive_seed(7, 0);
        assert_eq!(a, derive_seed(7, 0), "seed derivation must be pure");
        let seeds: Vec<u64> = (0..100).map(|i| derive_seed(7, i)).collect();
        let mut unique = seeds.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), seeds.len(), "collision in cell seeds");
        assert_ne!(derive_seed(7, 1), derive_seed(8, 1), "base seed must matter");
    }

    #[test]
    fn cell_rng_streams_diverge() {
        let points = [0.0, 1.0];
        let a = Cell {
            index: 0,
            params: &points[0],
            seed: derive_seed(0, 0),
        };
        let b = Cell {
            index: 1,
            params: &points[1],
            seed: derive_seed(0, 1),
        };
        assert_ne!(a.rng().next_u64_raw(), b.rng().next_u64_raw());
    }

    #[test]
    #[should_panic(expected = "step must be positive")]
    fn zero_step_rejected() {
        Grid::stepped(0.0, 1.0, 0.0);
    }
}
