//! The unified parameter-sweep engine.
//!
//! Every experiment in this repro is, at heart, a sweep: over request
//! period (exp2/exp3), over SPI configuration settings (exp1), over
//! transient energy or accelerator mix (ablations), over strategies
//! (validation). Before this subsystem each module hand-rolled its own
//! serial `while t <= max` loop; now a sweep is a [`Grid`] declaration
//! plus a per-[`Cell`] closure handed to a [`SweepRunner`].
//!
//! Guarantees:
//!
//! * **Determinism at any thread count** — cells are indexed, each cell's
//!   PRNG seed is derived from `(base_seed, index)` alone, and results are
//!   collected in grid order. `threads = 1` and `threads = N` produce
//!   byte-identical output (the sweep-determinism test suite asserts
//!   this down to rendered CSV bytes).
//! * **No work-stealing nondeterminism** — the grid is split into
//!   contiguous chunks, one per worker, so no synchronization is needed
//!   beyond `std::thread::scope`'s join.

pub mod grid;
pub mod sweep;

pub use grid::{Cell, Grid};
pub use sweep::SweepRunner;
