//! The unified parameter-sweep engine.
//!
//! Every experiment in this repro is, at heart, a sweep: over request
//! period (exp2/exp3), over SPI configuration settings (exp1), over
//! transient energy or accelerator mix (ablations), over strategies
//! (validation). Before this subsystem each module hand-rolled its own
//! serial `while t <= max` loop; now a sweep is a [`Grid`] declaration
//! plus a per-[`Cell`] closure handed to a [`SweepRunner`].
//!
//! Guarantees:
//!
//! * **Determinism at any thread count** — cells are indexed, each cell's
//!   PRNG seed is derived from `(base_seed, index)` alone, and results
//!   land in preassigned grid-index slots. `threads = 1` and
//!   `threads = N` produce byte-identical output (the sweep-determinism
//!   test suite asserts this down to rendered CSV bytes).
//! * **Deterministic work stealing** — workers claim cell batches from a
//!   shared atomic cursor, so uneven cell costs don't serialize on the
//!   slowest static chunk; the cursor redistributes only *which thread*
//!   runs a cell, never its seed or its result slot, so scheduling stays
//!   unobservable in the output.
//! * **Per-worker scratch state** — `run_with_state` hoists per-cell
//!   setup (platform builds, event-queue allocations) into a state each
//!   worker initializes once and reuses across its cells.

pub mod grid;
pub mod sweep;

pub use grid::{Cell, Grid};
pub use sweep::SweepRunner;
