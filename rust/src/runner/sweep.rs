//! The multi-threaded sweep executor.
//!
//! [`SweepRunner::run`] maps a closure over every [`Cell`] of a [`Grid`]
//! on `threads` scoped OS threads and returns the results in grid order.
//! The grid is split into contiguous chunks (one per worker) so each
//! worker writes only its own slice of the result vector — no locks, no
//! work-stealing, and therefore no scheduling-dependent ordering. Output
//! is byte-identical at any thread count provided the per-cell closure is
//! a pure function of `(cell.params, cell.index, cell.seed)`.

use crate::runner::grid::{derive_seed, Cell, Grid};

/// Executes parameter sweeps across a fixed number of threads.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
    seed: u64,
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::auto()
    }
}

impl SweepRunner {
    /// A runner with an explicit thread count, clamped to
    /// `1..=MAX_RUNNER_THREADS`. Oversubscription beyond the core count
    /// is allowed (useful for determinism testing) but bounded so an
    /// absurd `--threads` value cannot exhaust OS thread limits — cells
    /// beyond the cap simply queue on the capped workers.
    pub fn new(threads: usize) -> SweepRunner {
        SweepRunner {
            threads: threads.clamp(1, Self::MAX_RUNNER_THREADS),
            seed: 0,
        }
    }

    /// Hard ceiling on worker threads per sweep (well above any core
    /// count this runs on; far below OS thread limits).
    pub const MAX_RUNNER_THREADS: usize = 512;

    /// Single-threaded reference runner (the determinism baseline).
    pub fn single() -> SweepRunner {
        SweepRunner::new(1)
    }

    /// A runner using every available core.
    pub fn auto() -> SweepRunner {
        SweepRunner::new(Self::max_threads())
    }

    /// The machine's available parallelism (≥ 1).
    pub fn max_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Set the base seed from which every cell seed is derived.
    pub fn with_seed(mut self, seed: u64) -> SweepRunner {
        self.seed = seed;
        self
    }

    /// The worker-thread count this runner uses.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over every cell of `grid`, returning results in grid order.
    ///
    /// `f` must be a pure function of the cell (same cell → same result);
    /// under that contract the output is independent of `threads`.
    pub fn run<P, R, F>(&self, grid: &Grid<P>, f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(&Cell<'_, P>) -> R + Sync,
    {
        let n = grid.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.threads.min(n);
        let points = grid.points();
        let base_seed = self.seed;

        if threads == 1 {
            // Fast path: no thread spawn overhead for serial sweeps.
            return points
                .iter()
                .enumerate()
                .map(|(index, params)| {
                    f(&Cell {
                        index,
                        params,
                        seed: derive_seed(base_seed, index as u64),
                    })
                })
                .collect();
        }

        let mut results: Vec<Option<R>> = Vec::with_capacity(n);
        results.resize_with(n, || None);
        let chunk = n.div_ceil(threads);

        std::thread::scope(|scope| {
            for (k, out_chunk) in results.chunks_mut(chunk).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    let start = k * chunk;
                    for (j, slot) in out_chunk.iter_mut().enumerate() {
                        let index = start + j;
                        *slot = Some(f(&Cell {
                            index,
                            params: &points[index],
                            seed: derive_seed(base_seed, index as u64),
                        }));
                    }
                });
            }
        });

        results
            .into_iter()
            .map(|r| r.expect("every cell is assigned to exactly one worker"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_grid_order() {
        let grid = Grid::new((0..1000u64).collect());
        for threads in [1, 2, 3, 8, 64] {
            let out = SweepRunner::new(threads).run(&grid, |cell| *cell.params * 2);
            let expected: Vec<u64> = (0..1000).map(|x| x * 2).collect();
            assert_eq!(out, expected, "threads={threads}");
        }
    }

    #[test]
    fn cell_index_matches_point_position() {
        let grid = Grid::new((0..137usize).collect());
        let out = SweepRunner::new(4).run(&grid, |cell| (cell.index, *cell.params));
        for (i, (index, param)) in out.into_iter().enumerate() {
            assert_eq!(i, index);
            assert_eq!(i, param);
        }
    }

    #[test]
    fn seeded_cells_identical_across_thread_counts() {
        let grid = Grid::new(vec![(); 257]);
        let baseline = SweepRunner::single()
            .with_seed(42)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        for threads in [2, 4, 16] {
            let out = SweepRunner::new(threads)
                .with_seed(42)
                .run(&grid, |cell| cell.rng().next_u64_raw());
            assert_eq!(out, baseline, "threads={threads}");
        }
    }

    #[test]
    fn base_seed_changes_every_stream() {
        let grid = Grid::new(vec![(); 16]);
        let a = SweepRunner::single()
            .with_seed(1)
            .run(&grid, |cell| cell.seed);
        let b = SweepRunner::single()
            .with_seed(2)
            .run(&grid, |cell| cell.seed);
        assert!(a.iter().zip(&b).all(|(x, y)| x != y));
    }

    #[test]
    fn empty_grid_is_fine() {
        let grid: Grid<u64> = Grid::new(Vec::new());
        let out: Vec<u64> = SweepRunner::auto().run(&grid, |c| *c.params);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_cells_is_fine() {
        let grid = Grid::new(vec![1u64, 2, 3]);
        let out = SweepRunner::new(64).run(&grid, |c| *c.params + 10);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(SweepRunner::new(0).threads(), 1);
    }

    #[test]
    fn absurd_thread_counts_are_capped() {
        assert_eq!(
            SweepRunner::new(usize::MAX).threads(),
            SweepRunner::MAX_RUNNER_THREADS
        );
        // capped runner still produces ordered, correct results
        let grid = Grid::new((0..100u64).collect());
        let out = SweepRunner::new(usize::MAX).run(&grid, |c| *c.params);
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }
}
