//! The multi-threaded sweep executor.
//!
//! [`SweepRunner::run`] maps a closure over every [`Cell`] of a [`Grid`]
//! on `threads` scoped OS threads and returns the results in grid order.
//! Workers claim small contiguous batches of cells from a shared atomic
//! cursor (deterministic work stealing), so uneven per-cell costs — a
//! tuner rung whose candidates die at different item counts, a trace
//! column 100× heavier than a periodic one — no longer serialize on the
//! slowest static chunk.
//!
//! Determinism argument: every result has a *preassigned slot* (its grid
//! index), every cell's seed derives from `(base seed, index)` alone,
//! and the per-cell closure must be a pure function of
//! `(cell.params, cell.index, cell.seed)` — so which worker computes a
//! cell, and in which order, is unobservable in the output. The cursor
//! only redistributes *which thread* runs a cell; it never reorders or
//! reseeds them, which is why output stays byte-identical at any
//! `--threads N` (asserted down to rendered CSV bytes by
//! `tests/sweep_determinism.rs`, including an adversarially uneven
//! grid).
//!
//! [`SweepRunner::run_with_state`] additionally gives every worker a
//! lazily-created mutable scratch state (e.g. a reusable
//! [`SimWorker`](crate::strategies::simulate::SimWorker)), for cells
//! whose setup cost (platform build, event-queue allocation) would
//! otherwise repeat per cell. The same purity contract applies: the
//! state may cache *construction*, never leak results between cells.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runner::grid::{derive_seed, Cell, Grid};

/// Executes parameter sweeps across a fixed number of threads.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
    seed: u64,
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::auto()
    }
}

impl SweepRunner {
    /// A runner with an explicit thread count, clamped to
    /// `1..=MAX_RUNNER_THREADS`. Oversubscription beyond the core count
    /// is allowed (useful for determinism testing) but bounded so an
    /// absurd `--threads` value cannot exhaust OS thread limits — cells
    /// beyond the cap simply queue on the capped workers.
    pub fn new(threads: usize) -> SweepRunner {
        SweepRunner {
            threads: threads.clamp(1, Self::MAX_RUNNER_THREADS),
            seed: 0,
        }
    }

    /// Hard ceiling on worker threads per sweep (well above any core
    /// count this runs on; far below OS thread limits).
    pub const MAX_RUNNER_THREADS: usize = 512;

    /// Single-threaded reference runner (the determinism baseline).
    pub fn single() -> SweepRunner {
        SweepRunner::new(1)
    }

    /// A runner using every available core.
    pub fn auto() -> SweepRunner {
        SweepRunner::new(Self::max_threads())
    }

    /// The machine's available parallelism (≥ 1).
    pub fn max_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Set the base seed from which every cell seed is derived.
    pub fn with_seed(mut self, seed: u64) -> SweepRunner {
        self.seed = seed;
        self
    }

    /// The worker-thread count this runner uses.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over every cell of `grid`, returning results in grid order.
    ///
    /// `f` must be a pure function of the cell (same cell → same result);
    /// under that contract the output is independent of `threads`.
    pub fn run<P, R, F>(&self, grid: &Grid<P>, f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(&Cell<'_, P>) -> R + Sync,
    {
        self.run_with_state(grid, || (), |(), cell| f(cell))
    }

    /// [`run`](SweepRunner::run) with a per-worker scratch state: every
    /// worker thread calls `init` once (lazily, on its first claimed
    /// batch) and threads the resulting state mutably through its cells.
    ///
    /// Use this to hoist per-cell setup cost (platform construction,
    /// queue allocation) out of the hot loop. The determinism contract
    /// extends to the state: `f(&mut w, cell)` must produce the same
    /// result as with a freshly-initialized `w` — cache construction in
    /// the state, never results.
    pub fn run_with_state<P, W, R, I, F>(&self, grid: &Grid<P>, init: I, f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        I: Fn() -> W + Sync,
        F: Fn(&mut W, &Cell<'_, P>) -> R + Sync,
    {
        let n = grid.len();
        if n == 0 {
            return Vec::new();
        }
        let threads = self.threads.min(n);
        let points = grid.points();
        let base_seed = self.seed;
        let cell_at = |index: usize| Cell {
            index,
            params: &points[index],
            seed: derive_seed(base_seed, index as u64),
        };

        if threads == 1 {
            // Fast path: no thread spawn overhead for serial sweeps.
            let mut state = init();
            return (0..n).map(|index| f(&mut state, &cell_at(index))).collect();
        }

        // Deterministic work stealing: workers claim batches of cells
        // from a shared cursor and buffer (index, result) pairs; the
        // results then land in their preassigned grid-index slots. Small
        // batches keep uneven cell costs balanced while amortizing the
        // cursor traffic on huge cheap grids.
        let batch = (n / (threads * 16)).clamp(1, 64);
        let cursor = AtomicUsize::new(0);
        let mut results: Vec<Option<R>> = Vec::with_capacity(n);
        results.resize_with(n, || None);

        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    let (f, init, cursor, cell_at) = (&f, &init, &cursor, &cell_at);
                    scope.spawn(move || {
                        let mut out: Vec<(usize, R)> = Vec::new();
                        let mut state: Option<W> = None;
                        loop {
                            let start = cursor.fetch_add(batch, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let state = state.get_or_insert_with(init);
                            for index in start..(start + batch).min(n) {
                                out.push((index, f(state, &cell_at(index))));
                            }
                        }
                        out
                    })
                })
                .collect();
            for handle in handles {
                for (index, result) in handle.join().expect("sweep worker panicked") {
                    results[index] = Some(result);
                }
            }
        });

        results
            .into_iter()
            .map(|r| r.expect("every cell is claimed by exactly one worker"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_grid_order() {
        let grid = Grid::new((0..1000u64).collect());
        for threads in [1, 2, 3, 8, 64] {
            let out = SweepRunner::new(threads).run(&grid, |cell| *cell.params * 2);
            let expected: Vec<u64> = (0..1000).map(|x| x * 2).collect();
            assert_eq!(out, expected, "threads={threads}");
        }
    }

    #[test]
    fn cell_index_matches_point_position() {
        let grid = Grid::new((0..137usize).collect());
        let out = SweepRunner::new(4).run(&grid, |cell| (cell.index, *cell.params));
        for (i, (index, param)) in out.into_iter().enumerate() {
            assert_eq!(i, index);
            assert_eq!(i, param);
        }
    }

    #[test]
    fn seeded_cells_identical_across_thread_counts() {
        let grid = Grid::new(vec![(); 257]);
        let baseline = SweepRunner::single()
            .with_seed(42)
            .run(&grid, |cell| cell.rng().next_u64_raw());
        for threads in [2, 4, 16] {
            let out = SweepRunner::new(threads)
                .with_seed(42)
                .run(&grid, |cell| cell.rng().next_u64_raw());
            assert_eq!(out, baseline, "threads={threads}");
        }
    }

    #[test]
    fn base_seed_changes_every_stream() {
        let grid = Grid::new(vec![(); 16]);
        let a = SweepRunner::single()
            .with_seed(1)
            .run(&grid, |cell| cell.seed);
        let b = SweepRunner::single()
            .with_seed(2)
            .run(&grid, |cell| cell.seed);
        assert!(a.iter().zip(&b).all(|(x, y)| x != y));
    }

    #[test]
    fn empty_grid_is_fine() {
        let grid: Grid<u64> = Grid::new(Vec::new());
        let out: Vec<u64> = SweepRunner::auto().run(&grid, |c| *c.params);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_cells_is_fine() {
        let grid = Grid::new(vec![1u64, 2, 3]);
        let out = SweepRunner::new(64).run(&grid, |c| *c.params + 10);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(SweepRunner::new(0).threads(), 1);
    }

    #[test]
    fn work_stealing_keeps_grid_order_under_uneven_costs() {
        // cells spin for wildly different times: with static chunking the
        // expensive tail serializes; with work stealing the output must
        // still land in grid order, identical at every thread count
        let grid = Grid::new((0..200u64).collect());
        let work = |cell: &Cell<'_, u64>| {
            let spins = if cell.index % 50 == 0 { 20_000 } else { 10 };
            let mut acc = *cell.params;
            for i in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            (cell.index, acc)
        };
        let reference = SweepRunner::single().run(&grid, work);
        for threads in [2, 3, 8, 32] {
            let out = SweepRunner::new(threads).run(&grid, work);
            assert_eq!(out, reference, "threads={threads}");
        }
    }

    #[test]
    fn per_worker_state_is_initialized_lazily_and_reused() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let grid = Grid::new((0..500u64).collect());
        let inits = AtomicUsize::new(0);
        let runner = SweepRunner::new(4);
        let out = runner.run_with_state(
            &grid,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0u64 // per-worker scratch: counts this worker's cells
            },
            |scratch, cell| {
                *scratch += 1;
                *cell.params * 2
            },
        );
        assert_eq!(out, (0..500).map(|x| x * 2).collect::<Vec<_>>());
        let inits = inits.load(Ordering::Relaxed);
        assert!(inits >= 1 && inits <= 4, "workers init once each: {inits}");
    }

    #[test]
    fn state_results_match_stateless_at_any_thread_count() {
        let grid = Grid::new((0..97u64).collect());
        let reference = SweepRunner::single().run(&grid, |cell| cell.seed ^ *cell.params);
        for threads in [1, 4, 16] {
            let out = SweepRunner::new(threads).run_with_state(
                &grid,
                Vec::<u8>::new,
                |_scratch, cell| cell.seed ^ *cell.params,
            );
            assert_eq!(out, reference, "threads={threads}");
        }
    }

    #[test]
    fn absurd_thread_counts_are_capped() {
        assert_eq!(
            SweepRunner::new(usize::MAX).threads(),
            SweepRunner::MAX_RUNNER_THREADS
        );
        // capped runner still produces ordered, correct results
        let grid = Grid::new((0..100u64).collect());
        let out = SweepRunner::new(usize::MAX).run(&grid, |c| *c.params);
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }
}
