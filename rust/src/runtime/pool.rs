//! Per-thread runtime pool.
//!
//! PJRT client creation and HLO compilation are expensive (tens of ms);
//! the serving hot path must never pay them per request. The `xla`
//! crate's handles are `!Send` (Rc-backed), so the pool is thread-local:
//! one lazily-created CPU client and one compiled [`LstmRuntime`] per
//! artifacts directory *per thread*. The serving coordinator runs its
//! entire request loop on one thread, so in practice there is exactly one
//! client and one compiled runtime per process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::Result;

use crate::runtime::artifact::Manifest;
use crate::runtime::client::Client;
use crate::runtime::inference::LstmRuntime;

thread_local! {
    static CLIENT: RefCell<Option<Rc<Client>>> = const { RefCell::new(None) };
    static RUNTIMES: RefCell<HashMap<PathBuf, Rc<LstmRuntime>>> =
        RefCell::new(HashMap::new());
}

/// The thread's PJRT CPU client (created on first use).
pub fn client() -> Result<Rc<Client>> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(c) = slot.as_ref() {
            return Ok(c.clone());
        }
        let c = Rc::new(Client::cpu()?);
        *slot = Some(c.clone());
        Ok(c)
    })
}

/// Get (or build) the compiled runtime for an artifacts directory.
pub fn runtime(dir: impl AsRef<Path>) -> Result<Rc<LstmRuntime>> {
    let dir = dir.as_ref().to_path_buf();
    if let Some(rt) = RUNTIMES.with(|m| m.borrow().get(&dir).cloned()) {
        return Ok(rt);
    }
    let manifest = Manifest::load(&dir)?;
    let rt = Rc::new(LstmRuntime::load(client()?.as_ref(), manifest)?);
    RUNTIMES.with(|m| m.borrow_mut().insert(dir, rt.clone()));
    Ok(rt)
}

/// The default-artifacts runtime (used by the CLI and examples).
pub fn default_runtime() -> Result<Rc<LstmRuntime>> {
    runtime(crate::runtime::artifact::default_dir())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_returns_same_instance() {
        let dir = crate::runtime::artifact::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let a = runtime(&dir).unwrap();
        let b = runtime(&dir).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_dir_errors_cleanly() {
        assert!(runtime("/nonexistent/artifacts").is_err());
    }
}
