//! Typed inference API over the compiled LSTM artifacts.
//!
//! [`LstmRuntime`] is what the serving coordinator holds: compiled
//! executables for each model variant, shape-checked against the
//! manifest, plus the startup self-check proving numerical agreement with
//! the L2 JAX model that produced the artifacts.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::artifact::Manifest;
use crate::runtime::client::{Client, Executable};
use crate::util::units::Duration;

/// Which model variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// f32 forecast over a full window.
    Forecast,
    /// int8-activation (fixed-point FPGA-like) forecast.
    ForecastInt8,
}

impl Variant {
    /// The manifest artifact name this variant loads.
    pub fn artifact_name(&self) -> &'static str {
        match self {
            Variant::Forecast => "lstm_forecast",
            Variant::ForecastInt8 => "lstm_forecast_int8",
        }
    }
}

/// Result of one inference with its host-side latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferenceResult {
    /// The forecast value.
    pub forecast: f32,
    /// Host-side execution latency.
    pub latency: Duration,
}

/// Compiled runtime for the LSTM accelerator artifacts.
pub struct LstmRuntime {
    /// The loaded artifact manifest.
    pub manifest: Manifest,
    forecast: Executable,
    forecast_int8: Option<Executable>,
    /// Fixed-batch variant (one dispatch for a burst of windows).
    forecast_batch: Option<(Executable, usize)>,
    step: Executable,
}

impl LstmRuntime {
    /// Compile all artifacts in `manifest` on `client`.
    pub fn load(client: &Client, manifest: Manifest) -> Result<LstmRuntime> {
        let compile = |name: &str| -> Result<Executable> {
            let entry = manifest
                .entry(name)
                .with_context(|| format!("artifact '{name}' missing from manifest"))?;
            client.compile_hlo_file(manifest.hlo_path(entry))
        };
        let forecast = compile("lstm_forecast")?;
        let step = compile("lstm_step")?;
        let forecast_int8 = if manifest.entry("lstm_forecast_int8").is_some() {
            Some(compile("lstm_forecast_int8")?)
        } else {
            None
        };
        let forecast_batch = match manifest.entry("lstm_forecast_batch8") {
            Some(entry) => {
                let batch = entry.inputs[0][0];
                Some((compile("lstm_forecast_batch8")?, batch))
            }
            None => None,
        };
        Ok(LstmRuntime {
            manifest,
            forecast,
            forecast_int8,
            forecast_batch,
            step,
        })
    }

    /// Batch size of the batched artifact, if present.
    pub fn batch_size(&self) -> Option<usize> {
        self.forecast_batch.as_ref().map(|(_, b)| *b)
    }

    /// Run a fixed-size batch of windows in ONE executable dispatch.
    /// `windows` is row-major `(batch × window × input)`.
    pub fn forecast_batch(&self, windows: &[f32]) -> Result<Vec<f32>> {
        let (exe, batch) = self
            .forecast_batch
            .as_ref()
            .context("batched artifact not available")?;
        let (rows, cols) = self.window_shape();
        anyhow::ensure!(
            windows.len() == batch * rows * cols,
            "batch buffer has {} values, expected {batch}×{rows}×{cols}",
            windows.len()
        );
        let out = exe.run_f32(&[(
            &[*batch as i64, rows as i64, cols as i64],
            windows,
        )])?;
        anyhow::ensure!(out.len() == 1 && out[0].len() == *batch, "bad batch output");
        Ok(out.into_iter().next().unwrap())
    }

    /// Window length × channels expected by the forecast entry points.
    pub fn window_shape(&self) -> (usize, usize) {
        (self.manifest.window, self.manifest.input_size)
    }

    /// Run a forecast over a row-major `(window × input)` f32 buffer.
    pub fn forecast(&self, window: &[f32], variant: Variant) -> Result<InferenceResult> {
        let (rows, cols) = self.window_shape();
        anyhow::ensure!(
            window.len() == rows * cols,
            "window has {} values, expected {rows}×{cols}",
            window.len()
        );
        let exe = match variant {
            Variant::Forecast => &self.forecast,
            Variant::ForecastInt8 => self
                .forecast_int8
                .as_ref()
                .context("int8 artifact not available")?,
        };
        let start = Instant::now();
        let out = exe.run_f32(&[(&[rows as i64, cols as i64], window)])?;
        let latency = Duration::from_secs(start.elapsed().as_secs_f64());
        anyhow::ensure!(out.len() == 1 && out[0].len() == 1, "unexpected output arity");
        Ok(InferenceResult {
            forecast: out[0][0],
            latency,
        })
    }

    /// Run a single LSTM cell step: `(x, h, c) -> (h', c')`.
    pub fn step(&self, x: &[f32], h: &[f32], c: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let inp = self.manifest.input_size as i64;
        let hid = self.manifest.hidden_size as i64;
        let mut out = self.step.run_f32(&[
            (&[1, inp], x),
            (&[1, hid], h),
            (&[1, hid], c),
        ])?;
        anyhow::ensure!(out.len() == 2, "step must return (h, c)");
        let c_next = out.pop().unwrap();
        let h_next = out.pop().unwrap();
        Ok((h_next, c_next))
    }

    /// Startup self-check: run the manifest's known window through both
    /// variants and compare with the JAX-produced expectations. Returns
    /// the max absolute error observed.
    pub fn self_check(&self) -> Result<f32> {
        let sc = &self.manifest.selfcheck;
        let got = self.forecast(&sc.window, Variant::Forecast)?;
        let err_f32 = (got.forecast - sc.forecast).abs();
        anyhow::ensure!(
            err_f32 < 1e-4,
            "f32 self-check failed: rust={} jax={}",
            got.forecast,
            sc.forecast
        );
        let mut max_err = err_f32;
        if self.forecast_int8.is_some() {
            let got8 = self.forecast(&sc.window, Variant::ForecastInt8)?;
            let err_int8 = (got8.forecast - sc.forecast_int8).abs();
            anyhow::ensure!(
                err_int8 < 1e-4,
                "int8 self-check failed: rust={} jax={}",
                got8.forecast,
                sc.forecast_int8
            );
            max_err = max_err.max(err_int8);
        }
        log::info!("runtime self-check passed (max |err| = {max_err:.2e})");
        Ok(max_err)
    }

    /// Reconstruct the forecast by stepping the cell over the self-check
    /// window — proves the step artifact and the forecast artifact
    /// implement the same recurrence (used by integration tests).
    pub fn forecast_via_steps(&self, window: &[f32]) -> Result<Vec<f32>> {
        let (rows, cols) = self.window_shape();
        let hid = self.manifest.hidden_size;
        let mut h = vec![0f32; hid];
        let mut c = vec![0f32; hid];
        for t in 0..rows {
            let x = &window[t * cols..(t + 1) * cols];
            let (h2, c2) = self.step(x, &h, &c)?;
            h = h2;
            c = c2;
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::default_dir;

    fn runtime() -> Option<LstmRuntime> {
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        let client = Client::cpu().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        Some(LstmRuntime::load(&client, manifest).unwrap())
    }

    #[test]
    fn self_check_against_jax() {
        let Some(rt) = runtime() else { return };
        let err = rt.self_check().unwrap();
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn forecast_latency_measured() {
        let Some(rt) = runtime() else { return };
        let sc = rt.manifest.selfcheck.clone();
        let r = rt.forecast(&sc.window, Variant::Forecast).unwrap();
        assert!(r.latency.secs() > 0.0);
        assert!(r.latency.secs() < 1.0, "CPU inference should be fast");
    }

    #[test]
    fn bad_window_size_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.forecast(&[0.0; 7], Variant::Forecast).is_err());
    }

    #[test]
    fn int8_variant_differs_but_is_close() {
        let Some(rt) = runtime() else { return };
        let sc = rt.manifest.selfcheck.clone();
        let f = rt.forecast(&sc.window, Variant::Forecast).unwrap().forecast;
        let q = rt.forecast(&sc.window, Variant::ForecastInt8).unwrap().forecast;
        assert!((f - q).abs() < 0.1, "f32={f} int8={q}");
        assert_ne!(f, q);
    }

    #[test]
    fn batched_forecast_matches_singles() {
        let Some(rt) = runtime() else { return };
        let Some(batch) = rt.batch_size() else {
            eprintln!("skipping: no batched artifact");
            return;
        };
        let (rows, cols) = rt.window_shape();
        let base = rt.manifest.selfcheck.window.clone();
        // build `batch` distinct windows by shifting the self-check one
        let mut buffer = Vec::with_capacity(batch * rows * cols);
        let mut singles = Vec::new();
        for b in 0..batch {
            let shifted: Vec<f32> =
                base.iter().map(|v| v + 0.01 * b as f32).collect();
            singles.push(rt.forecast(&shifted, Variant::Forecast).unwrap().forecast);
            buffer.extend_from_slice(&shifted);
        }
        let batched = rt.forecast_batch(&buffer).unwrap();
        assert_eq!(batched.len(), batch);
        for (b, (one, many)) in singles.iter().zip(&batched).enumerate() {
            assert!((one - many).abs() < 1e-5, "lane {b}: {one} vs {many}");
        }
    }

    #[test]
    fn batched_forecast_rejects_bad_size() {
        let Some(rt) = runtime() else { return };
        if rt.batch_size().is_none() {
            return;
        }
        assert!(rt.forecast_batch(&[0.0; 10]).is_err());
    }

    #[test]
    fn stepping_matches_forecast_recurrence() {
        let Some(rt) = runtime() else { return };
        let sc = rt.manifest.selfcheck.clone();
        let h = rt.forecast_via_steps(&sc.window).unwrap();
        assert_eq!(h.len(), 20);
        // final hidden state must be bounded (sigmoid·tanh) and non-trivial
        assert!(h.iter().all(|v| v.abs() <= 1.0));
        assert!(h.iter().any(|v| v.abs() > 1e-3));
    }
}
