//! PJRT runtime (L3 side of the AOT bridge): loads the HLO text artifacts
//! produced by `python/compile/aot.py`, compiles them on the PJRT CPU
//! client and executes them on the request path. Python never runs here.

pub mod artifact;
pub mod client;
pub mod inference;
pub mod pool;

pub use artifact::Manifest;
pub use client::{Client, Executable};
pub use inference::{InferenceResult, LstmRuntime, Variant};
