//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` describes each AOT-lowered HLO module (entry
//! shapes, outputs) plus a numeric self-check (a known input window and
//! the forecast the JAX model produced for it), so the rust runtime can
//! prove end-to-end numerical agreement with L2 at startup.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Why the artifact manifest failed to load.
#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    /// The manifest file could not be read.
    #[error("io error reading {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    /// The manifest is not valid JSON.
    #[error("manifest parse error: {0}")]
    Parse(#[from] crate::util::json::JsonError),
    /// The manifest JSON is missing required fields.
    #[error("manifest malformed: {0}")]
    Malformed(String),
}

/// One AOT artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Artifact name (e.g. `forecast`).
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: PathBuf,
    /// Input tensor shapes (row-major f32 unless int8 path).
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes.
    pub outputs: Vec<Vec<usize>>,
}

/// The numeric self-check payload.
#[derive(Debug, Clone, PartialEq)]
pub struct SelfCheck {
    /// Flattened (WINDOW × INPUT) f32 window, row-major.
    pub window: Vec<f32>,
    /// Expected `lstm_forecast` output for that window.
    pub forecast: f32,
    /// Expected `lstm_forecast_int8` output.
    pub forecast_int8: f32,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// LSTM hidden size the artifacts were lowered with.
    pub hidden_size: usize,
    /// Model input size.
    pub input_size: usize,
    /// Input window length.
    pub window: usize,
    /// The lowered artifacts.
    pub artifacts: Vec<ArtifactEntry>,
    /// Golden input/output pair for the runtime self-check.
    pub selfcheck: SelfCheck,
}

fn malformed(msg: impl Into<String>) -> ManifestError {
    ManifestError::Malformed(msg.into())
}

fn shape_list(v: &Json, what: &str) -> Result<Vec<Vec<usize>>, ManifestError> {
    v.as_arr()
        .ok_or_else(|| malformed(format!("{what}: expected array of shapes")))?
        .iter()
        .map(|shape| {
            shape
                .as_arr()
                .ok_or_else(|| malformed(format!("{what}: expected shape array")))?
                .iter()
                .map(|d| {
                    d.as_u64()
                        .map(|d| d as usize)
                        .ok_or_else(|| malformed(format!("{what}: bad dim")))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ManifestError::Io {
            path: path.display().to_string(),
            source,
        })?;
        Self::parse(&text, dir)
    }

    /// Parse a manifest document rooted at `dir`.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ManifestError> {
        let root = Json::parse(text)?;
        let get_usize = |key: &str| -> Result<usize, ManifestError> {
            root.get(key)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .ok_or_else(|| malformed(format!("missing numeric field '{key}'")))
        };
        let artifacts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| malformed("missing 'artifacts' array"))?
            .iter()
            .map(|a| -> Result<ArtifactEntry, ManifestError> {
                Ok(ArtifactEntry {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| malformed("artifact missing 'name'"))?
                        .to_string(),
                    file: PathBuf::from(
                        a.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| malformed("artifact missing 'file'"))?,
                    ),
                    inputs: shape_list(
                        a.get("inputs").ok_or_else(|| malformed("missing inputs"))?,
                        "inputs",
                    )?,
                    outputs: shape_list(
                        a.get("outputs").ok_or_else(|| malformed("missing outputs"))?,
                        "outputs",
                    )?,
                })
            })
            .collect::<Result<Vec<_>, _>>()?;

        let sc = root
            .get("selfcheck")
            .ok_or_else(|| malformed("missing 'selfcheck'"))?;
        let window: Vec<f32> = sc
            .get("window")
            .and_then(Json::as_arr)
            .ok_or_else(|| malformed("selfcheck missing 'window'"))?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| malformed("selfcheck window has non-numbers"))?;
        let selfcheck = SelfCheck {
            window,
            forecast: sc
                .get("forecast")
                .and_then(Json::as_f64)
                .ok_or_else(|| malformed("selfcheck missing 'forecast'"))?
                as f32,
            forecast_int8: sc
                .get("forecast_int8")
                .and_then(Json::as_f64)
                .ok_or_else(|| malformed("selfcheck missing 'forecast_int8'"))?
                as f32,
        };

        let manifest = Manifest {
            dir,
            hidden_size: get_usize("hidden_size")?,
            input_size: get_usize("input_size")?,
            window: get_usize("window")?,
            artifacts,
            selfcheck,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<(), ManifestError> {
        if self.selfcheck.window.len() != self.window * self.input_size {
            return Err(malformed(format!(
                "selfcheck window has {} values, expected {}×{}",
                self.selfcheck.window.len(),
                self.window,
                self.input_size
            )));
        }
        for name in ["lstm_step", "lstm_forecast"] {
            if self.entry(name).is_none() {
                return Err(malformed(format!("required artifact '{name}' missing")));
            }
        }
        Ok(())
    }

    /// Look up an artifact by name.
    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// Default artifacts directory: `$IDLEWAIT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("IDLEWAIT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_manifest() -> String {
        r#"{
            "schema_version": 1, "seed": 5588,
            "hidden_size": 20, "input_size": 2, "window": 3,
            "quant_scale": 0.015, "dtype": "f32",
            "artifacts": [
                {"name": "lstm_step", "file": "lstm_step.hlo.txt",
                 "inputs": [[1,2],[1,20],[1,20]], "outputs": [[1,20],[1,20]]},
                {"name": "lstm_forecast", "file": "lstm_forecast.hlo.txt",
                 "inputs": [[3,2]], "outputs": [[1]]}
            ],
            "selfcheck": {"window_seed": 0, "forecast": -0.25, "forecast_int8": -0.24,
                          "window": [1,2,3,4,5,6]}
        }"#
        .to_string()
    }

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(&minimal_manifest(), PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.hidden_size, 20);
        assert_eq!(m.artifacts.len(), 2);
        let step = m.entry("lstm_step").unwrap();
        assert_eq!(step.inputs, vec![vec![1, 2], vec![1, 20], vec![1, 20]]);
        assert_eq!(m.hlo_path(step), PathBuf::from("/tmp/a/lstm_step.hlo.txt"));
        assert_eq!(m.selfcheck.forecast, -0.25);
    }

    #[test]
    fn window_size_mismatch_rejected() {
        let text = minimal_manifest().replace("\"window\": 3", "\"window\": 5");
        let e = Manifest::parse(&text, PathBuf::from("/tmp")).unwrap_err();
        assert!(e.to_string().contains("window has"));
    }

    #[test]
    fn missing_required_artifact_rejected() {
        let text = minimal_manifest().replace("lstm_forecast", "other_thing");
        let e = Manifest::parse(&text, PathBuf::from("/tmp")).unwrap_err();
        assert!(e.to_string().contains("lstm_forecast"));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Runs against `make artifacts` output when present (CI builds it).
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.hidden_size, 20);
        assert_eq!(m.input_size, 6);
        assert_eq!(m.window, 24);
        assert_eq!(m.selfcheck.window.len(), 144);
        assert!(m.entry("lstm_forecast_int8").is_some());
    }

    #[test]
    fn missing_dir_is_io_error() {
        assert!(matches!(
            Manifest::load("/nonexistent/dir"),
            Err(ManifestError::Io { .. })
        ));
    }
}
