//! PJRT client wrapper: load HLO text → compile → execute.
//!
//! Thin, typed layer over the `xla` crate following the pattern validated
//! in /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All computations were lowered with
//! `return_tuple=True`, so every result is a tuple literal that we
//! decompose into per-output f32 vectors.

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled, executable HLO module.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Executable name (for reports).
    pub name: String,
}

/// The process-wide PJRT CPU client.
pub struct Client {
    client: xla::PjRtClient,
}

impl Client {
    /// Create the PJRT CPU client (one per process is plenty; see
    /// [`crate::runtime::pool`] for the cached instance).
    pub fn cpu() -> Result<Client> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Client { client })
    }

    /// The PJRT platform this client runs on.
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO text file.
    pub fn compile_hlo_file(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let name = comp.name();
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name })
    }

    /// Compile HLO text from a string (tests / in-memory modules).
    pub fn compile_hlo_text(&self, text: &str) -> Result<Executable> {
        let dir = std::env::temp_dir().join(format!(
            "idlewait_hlo_{}_{}",
            std::process::id(),
            text.len()
        ));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("module.hlo.txt");
        std::fs::write(&path, text)?;
        let result = self.compile_hlo_file(&path);
        let _ = std::fs::remove_dir_all(&dir);
        result
    }
}

impl Executable {
    /// Execute with f32 tensor inputs (shape, row-major data) and return
    /// every output as a flat f32 vector.
    pub fn run_f32(&self, inputs: &[(&[i64], &[f32])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(shape, data)| -> Result<xla::Literal> {
                let expected: i64 = shape.iter().product();
                anyhow::ensure!(
                    expected as usize == data.len(),
                    "input shape {shape:?} wants {expected} values, got {}",
                    data.len()
                );
                Ok(xla::Literal::vec1(data).reshape(shape)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // Lowered with return_tuple=True → always a tuple, one element per
        // model output.
        let outputs = tuple.to_tuple().context("decomposing result tuple")?;
        outputs
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO: f32[2,2] matmul + broadcast add, returned as a
    /// 1-tuple — exercises the full load/compile/execute path without
    /// needing the python artifacts.
    const MATMUL_HLO: &str = r#"HloModule matmul_add, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  y = f32[2,2]{1,0} parameter(1)
  dot = f32[2,2]{1,0} dot(x, y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  c = f32[] constant(2)
  cb = f32[2,2]{1,0} broadcast(c), dimensions={}
  sum = f32[2,2]{1,0} add(dot, cb)
  ROOT t = (f32[2,2]{1,0}) tuple(sum)
}
"#;

    #[test]
    fn compile_and_execute_handwritten_hlo() {
        let client = Client::cpu().unwrap();
        let exe = client.compile_hlo_text(MATMUL_HLO).unwrap();
        let x = [1f32, 2.0, 3.0, 4.0];
        let y = [1f32, 1.0, 1.0, 1.0];
        let out = exe.run_f32(&[(&[2, 2], &x), (&[2, 2], &y)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let client = Client::cpu().unwrap();
        let exe = client.compile_hlo_text(MATMUL_HLO).unwrap();
        let bad = [1f32; 3];
        assert!(exe.run_f32(&[(&[2, 2], &bad), (&[2, 2], &bad)]).is_err());
    }

    #[test]
    fn garbage_hlo_fails_to_parse() {
        let client = Client::cpu().unwrap();
        assert!(client.compile_hlo_text("HloModule nope\nENTRY broken {").is_err());
    }
}
