//! Shared statistical harness for competitive-ratio properties.
//!
//! Several suites pin the same shape of claim: a policy's (expected) gap
//! energy stays within `bound × oracle` on a trace, up to a stated
//! tolerance. For randomized or online-learning policies the measured
//! cost is a sample mean over seeds, so a fixed seed count with a fixed
//! fudge factor either wastes simulations (too many seeds) or flakes
//! (too few). [`competitive_bound`] derives the seed count from the
//! evidence instead: it keeps adding seeded realizations until the 95%
//! confidence interval of the mean clears (or provably straddles) the
//! bound, then reports the interval so the asserting test can print
//! exactly how close the margin was.
//!
//! The helper never asserts itself — it returns a [`CompetitiveReport`]
//! and the caller checks [`CompetitiveReport::holds`], so it composes
//! with the mini-prop framework (whose properties are plain `bool`
//! functions and shrink on failure) as well as with direct `assert!`s.

/// The claim to check: measured cost vs `bound × oracle`, with explicit
/// tolerances and seed-count limits.
#[derive(Debug, Clone)]
pub struct CompetitiveSpec {
    /// Label for failure messages.
    pub name: &'static str,
    /// The clairvoyant baseline cost (same units as the cost function).
    pub oracle: f64,
    /// The competitive ratio being pinned (e.g. 2.0 or e/(e−1)).
    pub bound: f64,
    /// Multiplicative tolerance on the bound (sampling noise, FSM vs
    /// Table-2 config-energy differences).
    pub slack: f64,
    /// Additive tolerance (guards the oracle ≈ 0 corner).
    pub abs_tol: f64,
    /// Lower sanity floor as a fraction of the oracle: the mean must not
    /// fall below `floor_frac × oracle` (a cost materially *below* the
    /// optimum means the accounting, not the policy, is wrong). Use 0.0
    /// to disable.
    pub floor_frac: f64,
    /// Seeds to draw before the first interval check.
    pub min_seeds: usize,
    /// Hard cap on drawn seeds; reaching it stops extension and the
    /// interval is reported as-is.
    pub max_seeds: usize,
}

impl CompetitiveSpec {
    /// Default starting sample size.
    pub const DEFAULT_MIN_SEEDS: usize = 4;
    /// Default seed cap.
    pub const DEFAULT_MAX_SEEDS: usize = 24;

    /// A spec with the default tolerances (no slack, 1e-6 additive, no
    /// floor) and seed limits.
    pub fn new(name: &'static str, oracle: f64, bound: f64) -> CompetitiveSpec {
        CompetitiveSpec {
            name,
            oracle,
            bound,
            slack: 1.0,
            abs_tol: 1e-6,
            floor_frac: 0.0,
            min_seeds: Self::DEFAULT_MIN_SEEDS,
            max_seeds: Self::DEFAULT_MAX_SEEDS,
        }
    }
}

/// The measured outcome of a [`competitive_bound`] run.
#[derive(Debug, Clone)]
pub struct CompetitiveReport {
    /// Label copied from the spec.
    pub name: &'static str,
    /// Seeds actually drawn.
    pub seeds: usize,
    /// Sample mean of the per-seed costs.
    pub mean: f64,
    /// 95% confidence half-width of the mean (0 for a deterministic
    /// cost function — every draw identical).
    pub half_width: f64,
    /// The upper limit the claim allows:
    /// `bound × oracle × slack + abs_tol`.
    pub limit: f64,
    /// The lower sanity floor: `floor_frac × oracle − abs_tol`.
    pub floor: f64,
}

impl CompetitiveReport {
    /// Whether the claim holds: the whole confidence interval sits at or
    /// under the limit, and the mean respects the floor.
    pub fn holds(&self) -> bool {
        self.mean + self.half_width <= self.limit && self.mean >= self.floor
    }

    /// One-line summary for assertion messages.
    pub fn render(&self) -> String {
        format!(
            "{}: mean {:.6} ± {:.6} over {} seed(s), limit {:.6}, floor {:.6}",
            self.name, self.mean, self.half_width, self.seeds, self.limit, self.floor
        )
    }
}

/// The 95% half-width of the mean of `costs` (normal approximation,
/// sample variance); 0.0 below two samples.
fn half_width(costs: &[f64]) -> f64 {
    let n = costs.len();
    if n < 2 {
        return 0.0;
    }
    let mean = costs.iter().sum::<f64>() / n as f64;
    let var = costs.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / (n - 1) as f64;
    1.96 * (var / n as f64).sqrt()
}

/// Measure `cost(seed)` for seeds `0, 1, …`, extending the sample until
/// the 95% interval of the mean no longer straddles the spec's limit (a
/// clear pass or a clear fail) or `max_seeds` is reached, and return the
/// final interval. The seed sequence is fixed, so the whole procedure is
/// deterministic: the same spec and cost function always draw the same
/// seeds and produce the same report.
pub fn competitive_bound(
    spec: &CompetitiveSpec,
    mut cost: impl FnMut(u64) -> f64,
) -> CompetitiveReport {
    assert!(
        spec.oracle.is_finite() && spec.bound.is_finite() && spec.min_seeds >= 1,
        "{}: degenerate competitive spec",
        spec.name
    );
    let limit = spec.bound * spec.oracle * spec.slack + spec.abs_tol;
    let floor = spec.floor_frac * spec.oracle - spec.abs_tol;
    let mut costs: Vec<f64> = Vec::with_capacity(spec.min_seeds);
    while costs.len() < spec.min_seeds.max(1) {
        costs.push(cost(costs.len() as u64));
    }
    loop {
        let mean = costs.iter().sum::<f64>() / costs.len() as f64;
        let half = half_width(&costs);
        // stop on a decisive interval (entirely under or entirely over
        // the limit) or when the seed budget is spent
        let decisive = mean + half <= limit || mean - half > limit;
        if decisive || costs.len() >= spec.max_seeds {
            return CompetitiveReport {
                name: spec.name,
                seeds: costs.len(),
                mean,
                half_width: half,
                limit,
                floor,
            };
        }
        costs.push(cost(costs.len() as u64));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_cost_needs_only_the_minimum_seeds() {
        let spec = CompetitiveSpec::new("det", 1.0, 2.0);
        let report = competitive_bound(&spec, |_| 1.5);
        assert_eq!(report.seeds, spec.min_seeds);
        assert_eq!(report.half_width, 0.0);
        assert!(report.holds(), "{}", report.render());
    }

    #[test]
    fn noisy_cost_extends_the_sample_until_the_interval_clears() {
        // alternating draws whose mean (≈1.5) is inside the limit 1.582
        // but whose 4-seed interval straddles it: the helper must keep
        // drawing until the interval tightens under the limit
        let spec = CompetitiveSpec::new("noisy", 1.0, 1.582);
        let report = competitive_bound(&spec, |seed| if seed % 2 == 0 { 1.35 } else { 1.65 });
        assert!(report.seeds > spec.min_seeds, "{}", report.render());
        assert!(report.seeds <= spec.max_seeds);
        assert!(report.holds(), "{}", report.render());
    }

    #[test]
    fn violations_and_floor_breaches_are_reported_not_hidden() {
        let spec = CompetitiveSpec::new("violation", 1.0, 2.0);
        let report = competitive_bound(&spec, |_| 5.0);
        assert!(!report.holds(), "{}", report.render());
        // a decisively-over interval stops early instead of burning seeds
        assert!(report.seeds < spec.max_seeds, "{}", report.render());

        let spec = CompetitiveSpec {
            floor_frac: 0.95,
            ..CompetitiveSpec::new("floor", 1.0, 2.0)
        };
        let report = competitive_bound(&spec, |_| 0.5);
        assert!(!report.holds(), "{}", report.render());
    }
}
