//! Mini property-testing framework (proptest is not in the offline
//! vendor set).
//!
//! Deterministic, seed-reported, with linear input shrinking: on failure
//! the runner re-tries progressively "smaller" inputs (via the
//! [`Shrink`] trait) and reports the smallest failing case plus the seed
//! to reproduce. Scoped to what this project's invariants need — numeric
//! scalars and small tuples — not a general-purpose engine.

use crate::util::rng::Xoshiro256ss;

/// Number of cases per property (override with IDLEWAIT_PROP_CASES).
pub fn default_cases() -> u32 {
    std::env::var("IDLEWAIT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Generate a random value of `Self` from the PRNG.
pub trait Gen: Sized + std::fmt::Debug + Clone {
    /// Generate one random value.
    fn gen(rng: &mut Xoshiro256ss) -> Self;
}

/// Produce candidate "smaller" values for shrinking.
pub trait Shrink: Sized + Clone {
    /// Smaller candidate values for shrinking a failure.
    fn shrink(&self) -> Vec<Self>;
}

/// A uniform f64 in a range (inclusive lo, exclusive hi).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InRange<const LO: i64, const HI: i64>(pub f64);

impl<const LO: i64, const HI: i64> Gen for InRange<LO, HI> {
    fn gen(rng: &mut Xoshiro256ss) -> Self {
        InRange(rng.uniform(LO as f64, HI as f64))
    }
}

impl<const LO: i64, const HI: i64> Shrink for InRange<LO, HI> {
    fn shrink(&self) -> Vec<Self> {
        let lo = LO as f64;
        let mut out = Vec::new();
        // shrink toward the low end of the range
        let candidates = [lo, (self.0 + lo) / 2.0, self.0 - (self.0 - lo) * 0.1];
        for c in candidates {
            if c < self.0 && c >= lo {
                out.push(InRange(c));
            }
        }
        out
    }
}

/// A u64 below a bound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Below<const N: u64>(pub u64);

impl<const N: u64> Gen for Below<N> {
    fn gen(rng: &mut Xoshiro256ss) -> Self {
        Below(rng.below(N))
    }
}

impl<const N: u64> Shrink for Below<N> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.0 > 0 {
            out.push(Below(0));
            out.push(Below(self.0 / 2));
            out.push(Below(self.0 - 1));
        }
        out.dedup();
        out
    }
}

impl<A: Gen, B: Gen> Gen for (A, B) {
    fn gen(rng: &mut Xoshiro256ss) -> Self {
        (A::gen(rng), B::gen(rng))
    }
}

impl<A: Shrink + std::fmt::Debug, B: Shrink + std::fmt::Debug> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Gen, B: Gen, C: Gen> Gen for (A, B, C) {
    fn gen(rng: &mut Xoshiro256ss) -> Self {
        (A::gen(rng), B::gen(rng), C::gen(rng))
    }
}

impl<A, B, C> Shrink for (A, B, C)
where
    A: Shrink + std::fmt::Debug,
    B: Shrink + std::fmt::Debug,
    C: Shrink + std::fmt::Debug,
{
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Check `property` over `cases` random inputs; panic with the smallest
/// failing input (after bounded shrinking) and the reproduction seed.
pub fn check<T: Gen + Shrink>(name: &str, cases: u32, property: impl Fn(&T) -> bool) {
    let seed = std::env::var("IDLEWAIT_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Xoshiro256ss::new(seed);
    for case in 0..cases {
        let input = T::gen(&mut rng);
        if property(&input) {
            continue;
        }
        // shrink: repeatedly take the first failing shrink candidate
        let mut smallest = input.clone();
        let mut budget = 200;
        'shrinking: while budget > 0 {
            for candidate in smallest.shrink() {
                budget -= 1;
                if !property(&candidate) {
                    smallest = candidate;
                    continue 'shrinking;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed at case {case} (seed {seed}):\n  \
             original: {input:?}\n  shrunk:   {smallest:?}\n\
             reproduce with IDLEWAIT_PROP_SEED={seed}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check::<InRange<0, 100>>("nonneg", 128, |x| x.0 >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_reports() {
        check::<InRange<0, 100>>("always-false", 16, |_| false);
    }

    #[test]
    fn shrinking_moves_toward_lo() {
        let x = InRange::<10, 100>(50.0);
        for candidate in x.shrink() {
            assert!(candidate.0 < 50.0 && candidate.0 >= 10.0);
        }
    }

    #[test]
    #[should_panic]
    fn shrunk_failure_is_smaller_than_original() {
        // property fails for x >= 20; the shrinker should land near 20
        check::<InRange<0, 100>>("ge20", 256, |x| x.0 < 20.0);
    }

    #[test]
    fn tuples_generate_and_shrink() {
        check::<(InRange<1, 10>, Below<5>)>("tuple", 64, |(a, b)| {
            a.0 >= 1.0 && b.0 < 5
        });
        let t = (InRange::<0, 10>(5.0), Below::<10>(3));
        assert!(!t.shrink().is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro256ss::new(1);
        let mut b = Xoshiro256ss::new(1);
        for _ in 0..32 {
            assert_eq!(
                InRange::<0, 1000>::gen(&mut a).0,
                InRange::<0, 1000>::gen(&mut b).0
            );
        }
    }
}
