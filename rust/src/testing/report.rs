//! Bit-exact [`SimReport`] comparison.
//!
//! The fast-path kernel's contract is that two simulation paths (fast
//! vs golden `Board`-FSM, resumed prefix vs from-scratch) agree on
//! every reported quantity down to the last bit. This comparator is the
//! single maintained field list — the simulate unit tests and the
//! `tests/fastpath_equivalence.rs` integration suite both call it, so a
//! new `SimReport` field cannot silently drop out of one suite's
//! coverage.

use crate::strategies::simulate::SimReport;

/// Assert `a` and `b` agree on every `SimReport` field the experiments
/// read — floats compared by bit pattern, labels by string equality.
/// Panics with `what` as context on the first mismatch.
pub fn assert_sim_reports_bit_identical(a: &SimReport, b: &SimReport, what: &str) {
    assert_eq!(a.policy, b.policy, "{what}: policy label");
    assert_eq!(a.arrival, b.arrival, "{what}: arrival label");
    assert_eq!(a.items, b.items, "{what}: items");
    assert_eq!(
        a.energy_exact.joules().to_bits(),
        b.energy_exact.joules().to_bits(),
        "{what}: exact energy {} vs {}",
        a.energy_exact.joules(),
        b.energy_exact.joules()
    );
    assert_eq!(
        a.energy_measured.joules().to_bits(),
        b.energy_measured.joules().to_bits(),
        "{what}: measured energy"
    );
    assert_eq!(
        a.monitor_rel_error.to_bits(),
        b.monitor_rel_error.to_bits(),
        "{what}: monitor error"
    );
    assert_eq!(
        a.lifetime.secs().to_bits(),
        b.lifetime.secs().to_bits(),
        "{what}: lifetime"
    );
    assert_eq!(a.configurations, b.configurations, "{what}: configurations");
    assert_eq!(a.power_ons, b.power_ons, "{what}: power-ons");
    assert_eq!(a.late_requests, b.late_requests, "{what}: late requests");
    assert_eq!(a.decisions, b.decisions, "{what}: decisions");
    assert_eq!(
        a.mean_latency.secs().to_bits(),
        b.mean_latency.secs().to_bits(),
        "{what}: mean latency"
    );
    assert_eq!(
        a.sim_time.secs().to_bits(),
        b.sim_time.secs().to_bits(),
        "{what}: clock"
    );
    assert_eq!(a.retries, b.retries, "{what}: fault retries");
    assert_eq!(
        a.recovery_energy.joules().to_bits(),
        b.recovery_energy.joules().to_bits(),
        "{what}: recovery energy"
    );
    assert_eq!(a.shed_requests, b.shed_requests, "{what}: shed requests");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_default;
    use crate::coordinator::requests::Periodic;
    use crate::strategies::simulate::simulate;
    use crate::strategies::strategy::IdleWaiting;
    use crate::util::units::Duration;

    fn report(items: u64) -> SimReport {
        let mut cfg = paper_default();
        cfg.workload.max_items = Some(items);
        let mut arrivals = Periodic {
            period: Duration::from_millis(40.0),
        };
        simulate(&cfg, &mut IdleWaiting::baseline(), &mut arrivals)
    }

    #[test]
    fn identical_reports_pass() {
        let a = report(10);
        let b = report(10);
        assert_sim_reports_bit_identical(&a, &b, "identical runs");
    }

    #[test]
    #[should_panic(expected = "differs: items")]
    fn differing_reports_panic_with_context() {
        let a = report(10);
        let b = report(11);
        assert_sim_reports_bit_identical(&a, &b, "differs");
    }
}
