//! Test support: the mini property-testing framework used by unit and
//! integration tests (offline substitute for proptest — see DESIGN.md §3).

pub mod competitive;
pub mod prop;
pub mod report;

pub use competitive::{competitive_bound, CompetitiveReport, CompetitiveSpec};
pub use prop::{check, Below, Gen, InRange, Shrink};
pub use report::assert_sim_reports_bit_identical;
