//! Battery / energy-budget model.
//!
//! The paper's 320 mAh LiPo provides the 4147 J budget (E_Budget) that
//! bounds every experiment. The battery is a simple energy integrator —
//! the paper's analytical model treats it as an ideal energy reservoir,
//! and we follow that, with draw accounting and exhaustion detection.

use crate::util::units::{Duration, Energy, Power};

/// A draw request exceeded the remaining budget.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("energy budget exhausted: requested {requested:.6} J with {remaining:.6} J remaining")]
pub struct Exhausted {
    /// Joules requested by the draw.
    pub requested: f64,
    /// Joules that were still available.
    pub remaining: f64,
}

/// An ideal energy reservoir with draw tracking.
#[derive(Debug, Clone, PartialEq)]
pub struct Battery {
    capacity: Energy,
    drawn: Energy,
}

impl Battery {
    /// A full battery with the given capacity.
    pub fn new(capacity: Energy) -> Battery {
        assert!(capacity.joules() > 0.0);
        Battery {
            capacity,
            drawn: Energy::ZERO,
        }
    }

    /// The paper's battery: 320 mAh LiPo ≈ 4147 J.
    pub fn paper_budget() -> Battery {
        Battery::new(Energy::from_joules(crate::device::calib::BATTERY_BUDGET_J))
    }

    /// Total capacity.
    pub fn capacity(&self) -> Energy {
        self.capacity
    }

    /// Energy drawn so far.
    pub fn drawn(&self) -> Energy {
        self.drawn
    }

    /// Energy still available.
    pub fn remaining(&self) -> Energy {
        self.capacity - self.drawn
    }

    /// True once a draw has been refused.
    pub fn is_exhausted(&self) -> bool {
        self.drawn >= self.capacity
    }

    /// Fraction of capacity consumed, in [0, 1].
    pub fn depth_of_discharge(&self) -> f64 {
        (self.drawn / self.capacity).min(1.0)
    }

    /// Attempt to draw `amount`; fails (without drawing) if it would
    /// overdraw. This implements Eq 3's "≤ E_Budget" criterion: the item
    /// that would exceed the budget is *not* executed.
    pub fn try_draw(&mut self, amount: Energy) -> Result<(), Exhausted> {
        debug_assert!(amount.joules() >= 0.0, "negative draw");
        if self.drawn + amount > self.capacity {
            return Err(Exhausted {
                requested: amount.joules(),
                remaining: self.remaining().joules(),
            });
        }
        self.drawn += amount;
        Ok(())
    }

    /// Draw power over a duration (`P·t`), same overdraw semantics.
    pub fn try_draw_power(&mut self, power: Power, dur: Duration) -> Result<(), Exhausted> {
        self.try_draw(power * dur)
    }

    /// Saturation value for [`endurance_at`](Battery::endurance_at):
    /// 10^15 seconds (≈ 31.7 million years). Any draw small enough to
    /// hit this bound is indistinguishable from "forever" at the
    /// paper's time scales, and a finite cap keeps downstream lifetime
    /// arithmetic (subtraction, comparisons, CSV formatting) free of
    /// `inf`/`NaN`.
    pub fn endurance_cap() -> Duration {
        Duration::from_secs(1.0e15)
    }

    /// How long the battery can sustain `power` from its current level.
    ///
    /// Total over every input: a zero, negative, or `NaN` power draw
    /// cannot run the battery down, so the result saturates at
    /// [`endurance_cap`](Battery::endurance_cap) instead of dividing
    /// through to `inf`/`NaN`. Finite positive draws are also clamped
    /// to the same cap so the return value is always a finite,
    /// comparable duration.
    pub fn endurance_at(&self, power: Power) -> Duration {
        let watts = power.watts();
        if watts.is_nan() || watts <= 0.0 {
            return Battery::endurance_cap();
        }
        let t = self.remaining() / power;
        if t > Battery::endurance_cap() {
            Battery::endurance_cap()
        } else {
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_budget_capacity() {
        let b = Battery::paper_budget();
        assert_eq!(b.capacity().joules(), 4147.0);
        assert_eq!(b.remaining().joules(), 4147.0);
        assert!(!b.is_exhausted());
    }

    #[test]
    fn draw_accumulates() {
        let mut b = Battery::new(Energy::from_joules(1.0));
        b.try_draw(Energy::from_millijoules(400.0)).unwrap();
        b.try_draw(Energy::from_millijoules(300.0)).unwrap();
        assert!((b.remaining().millijoules() - 300.0).abs() < 1e-9);
        assert!((b.depth_of_discharge() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn overdraw_rejected_without_side_effect() {
        let mut b = Battery::new(Energy::from_joules(1.0));
        b.try_draw(Energy::from_joules(0.9)).unwrap();
        let before = b.drawn();
        let err = b.try_draw(Energy::from_joules(0.2)).unwrap_err();
        assert!(err.remaining > 0.09 && err.remaining < 0.11);
        assert_eq!(b.drawn(), before, "failed draw must not consume energy");
    }

    #[test]
    fn eq3_semantics_items_until_budget() {
        // n_max items of 11.983 mJ within 4147 J → 346,073 (paper Fig 8)
        // The battery loop must realize exactly floor(budget / E_item);
        // with the calibrated 11.983 mJ On-Off item this is the paper's
        // n ≈ 346,073 (the analytical module owns the exact constant).
        let mut b = Battery::paper_budget();
        let item = Energy::from_millijoules(11.983);
        let mut n = 0u64;
        while b.try_draw(item).is_ok() {
            n += 1;
        }
        let expected = (4147.0f64 / 0.011983).floor() as u64;
        assert!(n.abs_diff(expected) <= 1, "n={n} expected≈{expected}");
        assert!(n.abs_diff(346_073) < 150, "n={n} vs paper 346,073");
    }

    #[test]
    fn draw_power_over_duration() {
        let mut b = Battery::new(Energy::from_joules(1.0));
        b.try_draw_power(Power::from_milliwatts(134.3), Duration::from_secs(1.0))
            .unwrap();
        assert!((b.drawn().millijoules() - 134.3).abs() < 1e-9);
    }

    #[test]
    fn endurance() {
        let b = Battery::paper_budget();
        let t = b.endurance_at(Power::from_milliwatts(134.3));
        // ≈ 4147/0.1343 s ≈ 8.58 h — the paper's Idle-Waiting avg lifetime
        assert!((t.hours() - 8.577).abs() < 0.01, "{}", t.hours());
    }

    #[test]
    fn endurance_is_total_at_degenerate_power() {
        let b = Battery::paper_budget();
        let cap = Battery::endurance_cap();
        // zero, negative, and NaN draws saturate instead of producing
        // inf/NaN durations
        assert_eq!(b.endurance_at(Power::from_watts(0.0)), cap);
        assert_eq!(b.endurance_at(Power::from_watts(-1.0)), cap);
        assert_eq!(b.endurance_at(Power::from_watts(f64::NAN)), cap);
        // a vanishingly small but positive draw clamps to the same cap
        assert_eq!(b.endurance_at(Power::from_watts(1e-30)), cap);
        assert!(cap.secs().is_finite());
        // ordinary draws are untouched by the clamp
        let t = b.endurance_at(Power::from_milliwatts(134.3));
        assert!(t < cap);
        assert!((t.hours() - 8.577).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        Battery::new(Energy::ZERO);
    }
}
