//! The Spartan-7 FPGA device state machine.
//!
//! Tracks power state, configuration state (SRAM — lost on power-off) and
//! legality of operations; the strategy simulations and the serving
//! coordinator drive this machine and account energy from the state/phase
//! powers. Invalid transitions (e.g. inference while unconfigured, data
//! transfer in retention mode) are hard errors — they would be silent
//! wrong-energy bugs otherwise.

use std::sync::Arc;

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::config_fsm::ConfigProfile;
use crate::device::flash::{Flash, FlashError};
use crate::device::rails::{PowerSaving, RailSet};
use crate::util::units::{Energy, Power};

/// Why an FPGA operation was refused.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FpgaError {
    /// Operation requires power; the rails are down.
    #[error("operation requires the FPGA powered on (state: {0})")]
    PoweredOff(&'static str),
    /// Operation requires a loaded configuration.
    #[error("operation requires a configured FPGA")]
    NotConfigured,
    /// Operation invalid in the current state.
    #[error("operation requires operational rails (currently in {0} power-saving)")]
    NotOperational(&'static str),
    /// The configuration source failed.
    #[error(transparent)]
    Flash(#[from] FlashError),
}

/// FPGA top-level state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpgaState {
    /// All FPGA rails down; configuration lost.
    Off,
    /// Rails up, fabric unconfigured (before/without configuration).
    Unconfigured,
    /// Configured and idle, under a power-saving setting.
    Idle(PowerSaving),
    /// Configured and executing a workload phase.
    Busy,
}

impl FpgaState {
    /// State name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            FpgaState::Off => "off",
            FpgaState::Unconfigured => "unconfigured",
            FpgaState::Idle(_) => "idle",
            FpgaState::Busy => "busy",
        }
    }
}

/// The FPGA device model.
#[derive(Debug, Clone)]
pub struct Fpga {
    /// Device model.
    pub model: FpgaModel,
    /// Current power/configuration state.
    pub state: FpgaState,
    rails: RailSet,
    /// Name of the accelerator currently configured, if any (shared so
    /// the per-configuration hot path never allocates).
    configured_with: Option<Arc<str>>,
    /// Total configurations performed (the quantity the paper minimizes).
    pub configurations: u64,
    /// Total power-on events (each costs the inrush transient).
    pub power_ons: u64,
}

impl Fpga {
    /// A powered-off FPGA of the given model.
    pub fn new(model: FpgaModel) -> Fpga {
        Fpga {
            model,
            state: FpgaState::Off,
            rails: RailSet::new(),
            configured_with: None,
            configurations: 0,
            power_ons: 0,
        }
    }

    /// True when a configuration is loaded (idle or busy).
    pub fn is_configured(&self) -> bool {
        self.configured_with.is_some()
    }

    /// Name of the loaded image, if configured.
    pub fn configured_with(&self) -> Option<&str> {
        self.configured_with.as_deref()
    }

    /// Power the FPGA rails up. Returns the inrush/ramp transient energy
    /// the power cycle costs (DESIGN.md §6).
    pub fn power_on(&mut self) -> Energy {
        debug_assert!(self.state == FpgaState::Off, "double power-on");
        self.rails.power_up();
        self.state = FpgaState::Unconfigured;
        self.power_ons += 1;
        Energy::from_millijoules(crate::device::calib::POWER_ON_TRANSIENT_MJ)
    }

    /// Cut the rails. SRAM configuration is lost (the paper's core
    /// problem statement §3).
    pub fn power_off(&mut self) {
        self.rails.power_down();
        self.configured_with = None;
        self.state = FpgaState::Off;
    }

    /// Run the configuration FSM from `flash` slot `slot` via `spi`.
    /// Returns the stage profile whose time/energy the caller accounts.
    pub fn configure(
        &mut self,
        flash: &Flash,
        slot: &str,
        spi: SpiConfig,
    ) -> Result<ConfigProfile, FpgaError> {
        if self.state == FpgaState::Off {
            return Err(FpgaError::PoweredOff(self.state.name()));
        }
        flash.check_spi(&spi)?;
        let image = flash.image(slot)?;
        let profile = ConfigProfile::compute(self.model, spi, image);
        self.mark_configured(Arc::from(slot));
        Ok(profile)
    }

    /// Record a completed configuration: the bookkeeping tail of
    /// [`Fpga::configure`] (slot name, counter, idle state), split out so
    /// the precomputed-cost fast path
    /// ([`GapCostTable`](crate::strategies::replay::GapCostTable)) can
    /// skip the profile recomputation while keeping counters and state
    /// bit-identical to the golden path. The caller must have powered the
    /// rails on first.
    pub fn mark_configured(&mut self, slot: Arc<str>) {
        debug_assert!(
            self.state != FpgaState::Off,
            "configuration requires powered rails"
        );
        self.configured_with = Some(slot);
        self.configurations += 1;
        self.state = FpgaState::Idle(PowerSaving::BASELINE);
    }

    /// Enter idle under a power-saving configuration (paper §4.2).
    pub fn enter_idle(&mut self, saving: PowerSaving) -> Result<(), FpgaError> {
        match self.state {
            FpgaState::Off => Err(FpgaError::PoweredOff("off")),
            FpgaState::Unconfigured => Err(FpgaError::NotConfigured),
            FpgaState::Idle(_) | FpgaState::Busy => {
                self.rails.enter_idle(saving);
                self.state = FpgaState::Idle(saving);
                Ok(())
            }
        }
    }

    /// Leave idle and begin a workload phase (data load / inference /
    /// offload). Exiting power-saving restores operational rails; the
    /// paper verified configuration survives this on hardware.
    pub fn begin_work(&mut self) -> Result<(), FpgaError> {
        match self.state {
            FpgaState::Off => Err(FpgaError::PoweredOff("off")),
            FpgaState::Unconfigured => Err(FpgaError::NotConfigured),
            FpgaState::Idle(_) => {
                self.rails.exit_idle();
                debug_assert!(self.rails.operational());
                self.state = FpgaState::Busy;
                Ok(())
            }
            FpgaState::Busy => Ok(()),
        }
    }

    /// Finish the workload phases, returning to baseline idle.
    pub fn finish_work(&mut self) -> Result<(), FpgaError> {
        match self.state {
            FpgaState::Busy => {
                self.state = FpgaState::Idle(PowerSaving::BASELINE);
                Ok(())
            }
            _ => Err(FpgaError::NotOperational(self.state.name())),
        }
    }

    /// Static power draw of the FPGA-side rails in the current state.
    /// (Active phases add their Table 2 dynamic power on top.)
    pub fn static_power(&self) -> Power {
        match self.state {
            FpgaState::Off => {
                // Only the always-on flash floor remains on the board.
                let mut rails = RailSet::new();
                rails.power_down();
                rails.static_power()
            }
            _ => self.rails.static_power(),
        }
    }

    /// Idle power in the given saving mode (Table 3 query).
    pub fn idle_power(saving: PowerSaving) -> Power {
        RailSet::idle_power(saving)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::bitstream::Bitstream;

    fn setup() -> (Fpga, Flash) {
        let mut flash = Flash::new();
        flash.program(
            "lstm",
            Bitstream::lstm_accelerator(FpgaModel::Xc7s15),
            true,
        );
        (Fpga::new(FpgaModel::Xc7s15), flash)
    }

    #[test]
    fn full_lifecycle() {
        let (mut fpga, flash) = setup();
        let inrush = fpga.power_on();
        assert!((inrush.millijoules() - 0.1244).abs() < 1e-9);
        let profile = fpga.configure(&flash, "lstm", SpiConfig::optimal()).unwrap();
        assert!((profile.total_energy().millijoules() - 11.85).abs() < 0.02);
        assert!(fpga.is_configured());
        fpga.begin_work().unwrap();
        fpga.finish_work().unwrap();
        fpga.enter_idle(PowerSaving::M12).unwrap();
        assert_eq!(fpga.state, FpgaState::Idle(PowerSaving::M12));
        fpga.power_off();
        assert!(!fpga.is_configured());
        assert_eq!(fpga.configurations, 1);
        assert_eq!(fpga.power_ons, 1);
    }

    #[test]
    fn configure_while_off_fails() {
        let (mut fpga, flash) = setup();
        assert!(matches!(
            fpga.configure(&flash, "lstm", SpiConfig::optimal()),
            Err(FpgaError::PoweredOff(_))
        ));
    }

    #[test]
    fn work_requires_configuration() {
        let (mut fpga, _) = setup();
        fpga.power_on();
        assert!(matches!(fpga.begin_work(), Err(FpgaError::NotConfigured)));
        assert!(matches!(
            fpga.enter_idle(PowerSaving::BASELINE),
            Err(FpgaError::NotConfigured)
        ));
    }

    #[test]
    fn power_off_loses_configuration() {
        let (mut fpga, flash) = setup();
        fpga.power_on();
        fpga.configure(&flash, "lstm", SpiConfig::optimal()).unwrap();
        fpga.power_off();
        fpga.power_on();
        // must reconfigure — SRAM config is gone
        assert!(matches!(fpga.begin_work(), Err(FpgaError::NotConfigured)));
    }

    #[test]
    fn idle_power_saving_survives_work_cycles() {
        let (mut fpga, flash) = setup();
        fpga.power_on();
        fpga.configure(&flash, "lstm", SpiConfig::optimal()).unwrap();
        fpga.enter_idle(PowerSaving::M12).unwrap();
        let idle_p = fpga.static_power();
        assert!((idle_p.milliwatts() - 24.0).abs() < 0.05);
        fpga.begin_work().unwrap();
        assert!(fpga.static_power() > idle_p); // operational rails restored
        fpga.finish_work().unwrap();
        assert!(fpga.is_configured());
    }

    #[test]
    fn off_state_draws_only_flash_floor() {
        let (fpga, _) = setup();
        assert!((fpga.static_power().milliwatts() - 15.2).abs() < 1e-9);
    }

    #[test]
    fn missing_slot_propagates() {
        let (mut fpga, flash) = setup();
        fpga.power_on();
        assert!(matches!(
            fpga.configure(&flash, "nonexistent", SpiConfig::optimal()),
            Err(FpgaError::Flash(FlashError::EmptySlot(_)))
        ));
    }

    #[test]
    fn finish_without_begin_fails() {
        let (mut fpga, _) = setup();
        assert!(fpga.finish_work().is_err());
    }
}
