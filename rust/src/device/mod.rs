//! Device substrate: models of every hardware component on the paper's
//! heterogeneous platform (Fig 3) that affects energy.
//!
//! * [`calib`] — every fitted/datasheet constant, unit-tested against the
//!   paper's published numbers.
//! * [`bitstream`] / [`compression`] — synthetic 7-series frame streams
//!   and the MFWR-style dedup compressor (ratios emerge, not hardcoded).
//! * [`spi`] / [`flash`] — configuration-port link timing/power and the
//!   NOR flash with its 15.2 mW standby floor.
//! * [`config_fsm`] — the Fig 4 configuration FSM; produces the per-stage
//!   profiles Experiment 1 sweeps.
//! * [`regulator`] / [`rails`] — per-rail power tree with Method 1 gating
//!   and Method 2 retention undervolting (reproduces Table 3).
//! * [`fpga`] / [`mcu`] / [`battery`] / [`monitor`] — the Spartan-7 state
//!   machine, the RP2040 request source, the 4147 J budget and the
//!   PAC1934 sampling monitor.
//! * [`faults`] — deterministic, seeded fault injection (configuration
//!   CRC/SPI/brownout/flash scenarios) and the retry/backoff policy.
//! * [`board`] — the assembled platform the simulations drive.

pub mod battery;
pub mod bitstream;
pub mod board;
pub mod calib;
pub mod compression;
pub mod config_fsm;
pub mod faults;
pub mod flash;
pub mod fpga;
pub mod mcu;
pub mod monitor;
pub mod rails;
pub mod regulator;
pub mod spi;

pub use battery::Battery;
pub use bitstream::Bitstream;
pub use board::Board;
pub use config_fsm::ConfigProfile;
pub use flash::Flash;
pub use fpga::{Fpga, FpgaState};
pub use mcu::Mcu;
pub use monitor::Pac1934;
pub use rails::{PowerSaving, RailSet};
