//! The FPGA configuration finite-state machine (paper Fig 4).
//!
//! Stages on power-up of an SRAM FPGA:
//!
//! ```text
//! Power-On → Setup (POR, clear configuration memory, mode sample; 27 ms,
//!            model-dependent, not optimizable)
//!          → Load Configuration Data (the stage Experiment 1 optimizes:
//!            SPI buswidth × clock frequency × compression)
//!          → Startup (GTS release, DONE; sub-ms, folded per the paper)
//! ```
//!
//! [`ConfigProfile::compute`] produces the per-stage time/power/energy
//! breakdown for a given device, SPI setting and stored image — the exact
//! quantity Fig 7 plots in its three columns (configuration phase, Setup
//! stage, Bitstream Loading stage).

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::calib::{SETUP_POWER, SETUP_SUBSTAGES, SETUP_TIME, STARTUP_TIME};
use crate::device::flash::StoredImage;
use crate::device::spi::{loading_power, transfer_time};
use crate::util::units::{Duration, Energy, Power};

/// A stage was requested that the configuration FSM does not produce.
/// Surfaced through config validation instead of panicking mid-run.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("no stage named '{0}' in the configuration profile (expected one of: setup, bitstream_loading, startup)")]
pub struct UnknownStage(pub String);

/// One stage of the configuration phase.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    /// Stage name (`setup`, `bitstream_loading`, `startup`).
    pub name: &'static str,
    /// Stage duration at the profiled SPI setting.
    pub time: Duration,
    /// Average power over the stage.
    pub power: Power,
}

impl Stage {
    /// Stage energy: `power × time`.
    pub fn energy(&self) -> Energy {
        self.power * self.time
    }
}

/// Complete per-stage profile of one configuration phase.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigProfile {
    /// Device the profile was computed for.
    pub model: FpgaModel,
    /// SPI setting the profile was computed at.
    pub spi: SpiConfig,
    /// The FSM stages, in execution order.
    pub stages: Vec<Stage>,
}

impl ConfigProfile {
    /// The stage names `compute()` emits, in FSM order — the single
    /// source of truth shared by the stage lookups, the validation
    /// tripwire and the tests.
    pub const STAGE_NAMES: [&'static str; 3] = ["setup", "bitstream_loading", "startup"];

    /// Compute the profile for loading `image` on `model` through `spi`.
    pub fn compute(model: FpgaModel, spi: SpiConfig, image: &StoredImage) -> ConfigProfile {
        let [setup, loading, startup] = Self::STAGE_NAMES;
        let bits = image.stream_bits();
        let stages = vec![
            Stage {
                name: setup,
                time: SETUP_TIME,
                power: SETUP_POWER,
            },
            Stage {
                name: loading,
                time: transfer_time(&spi, bits),
                power: loading_power(model, &spi),
            },
            Stage {
                name: startup,
                time: STARTUP_TIME,
                power: SETUP_POWER, // same rail state; zero-duration anyway
            },
        ];
        ConfigProfile { model, spi, stages }
    }

    /// Look up a stage by name. Unknown names are a proper error (they
    /// used to panic), so config-driven stage references can be rejected
    /// at validation time rather than aborting a sweep mid-run.
    pub fn stage(&self, name: &str) -> Result<&Stage, UnknownStage> {
        self.stages
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| UnknownStage(name.to_string()))
    }

    /// The setup stage (device init; constant across SPI settings).
    pub fn setup(&self) -> &Stage {
        self.stage(Self::STAGE_NAMES[0])
            .expect("compute() always emits a setup stage")
    }

    /// The bitstream-loading stage (the part the SPI setting scales).
    pub fn loading(&self) -> &Stage {
        self.stage(Self::STAGE_NAMES[1])
            .expect("compute() always emits a bitstream_loading stage")
    }

    /// Total configuration-phase time (the paper's T_config).
    pub fn total_time(&self) -> Duration {
        self.stages
            .iter()
            .fold(Duration::ZERO, |acc, s| acc + s.time)
    }

    /// Total configuration-phase energy (the paper's E_config).
    pub fn total_energy(&self) -> Energy {
        self.stages.iter().map(|s| s.energy()).sum()
    }

    /// Time-weighted average power over the configuration phase — the
    /// quantity Table 2 reports as "Configuration: 327.9 mW".
    pub fn avg_power(&self) -> Power {
        self.total_energy() / self.total_time()
    }

    /// Fig 4 sub-stage breakdown of the setup stage (reporting only).
    pub fn setup_substages(&self) -> Vec<Stage> {
        SETUP_SUBSTAGES
            .iter()
            .map(|(name, time)| Stage {
                name,
                time: *time,
                power: SETUP_POWER,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::bitstream::Bitstream;

    fn profile(spi: SpiConfig) -> ConfigProfile {
        let image = StoredImage::new(Bitstream::lstm_accelerator(FpgaModel::Xc7s15), spi.compressed);
        ConfigProfile::compute(FpgaModel::Xc7s15, spi, &image)
    }

    #[test]
    fn optimal_setting_reproduces_table2_configuration_row() {
        let p = profile(SpiConfig::optimal());
        // paper: 36.145 ms, 327.9 mW, 11.85 mJ
        assert!((p.total_time().millis() - 36.145).abs() < 0.01, "{}", p.total_time().millis());
        assert!((p.avg_power().milliwatts() - 327.9).abs() < 0.4, "{}", p.avg_power().milliwatts());
        assert!((p.total_energy().millijoules() - 11.85).abs() < 0.02, "{}", p.total_energy().millijoules());
    }

    #[test]
    fn worst_setting_reproduces_fig7_endpoint() {
        let p = profile(SpiConfig::worst());
        // paper: 41.4× slower, 475.56 mJ
        assert!((p.total_time().millis() - 1496.6).abs() < 1.5, "{}", p.total_time().millis());
        assert!((p.total_energy().millijoules() - 475.56).abs() < 1.0, "{}", p.total_energy().millijoules());
    }

    #[test]
    fn headline_ratios_hold() {
        let opt = profile(SpiConfig::optimal());
        let worst = profile(SpiConfig::worst());
        let time_ratio = worst.total_time() / opt.total_time();
        let energy_ratio = worst.total_energy() / opt.total_energy();
        assert!((time_ratio - 41.4).abs() < 0.1, "time ratio {time_ratio}");
        assert!((energy_ratio - 40.13).abs() < 0.15, "energy ratio {energy_ratio}");
    }

    #[test]
    fn xc7s25_reproduces_section52() {
        let image = StoredImage::new(Bitstream::lstm_accelerator(FpgaModel::Xc7s25), true);
        let p = ConfigProfile::compute(FpgaModel::Xc7s25, SpiConfig::optimal(), &image);
        // paper: 38.09 ms, 13.75 mJ
        assert!((p.total_time().millis() - 38.09).abs() < 0.05, "{}", p.total_time().millis());
        assert!((p.total_energy().millijoules() - 13.75).abs() < 0.05, "{}", p.total_energy().millijoules());
    }

    #[test]
    fn setup_stage_is_constant_across_settings() {
        for spi in SpiConfig::sweep() {
            let p = profile(spi);
            assert_eq!(p.setup().time, SETUP_TIME);
            assert_eq!(p.setup().power, SETUP_POWER);
        }
    }

    #[test]
    fn loading_time_monotone_decreasing_in_rate() {
        let mut last = Duration::from_secs(f64::INFINITY);
        for &f in &SpiConfig::FREQS_MHZ {
            let p = profile(SpiConfig {
                buswidth: 4,
                freq_mhz: f,
                compressed: true,
            });
            assert!(p.loading().time < last);
            last = p.loading().time;
        }
    }

    #[test]
    fn substages_sum_to_setup() {
        let p = profile(SpiConfig::optimal());
        let total: Duration = p
            .setup_substages()
            .iter()
            .fold(Duration::ZERO, |acc, s| acc + s.time);
        assert!((total.secs() - p.setup().time.secs()).abs() < 1e-12);
    }

    #[test]
    fn total_time_is_stage_sum() {
        let p = profile(SpiConfig::optimal());
        let sum: Duration = p.stages.iter().fold(Duration::ZERO, |a, s| a + s.time);
        assert_eq!(p.total_time().secs(), sum.secs());
    }

    #[test]
    fn unknown_stage_is_an_error_not_a_panic() {
        let err = profile(SpiConfig::optimal()).stage("warp").unwrap_err();
        assert_eq!(err, UnknownStage("warp".to_string()));
        assert!(err.to_string().contains("no stage named 'warp'"));
    }

    #[test]
    fn known_stages_resolve() {
        let p = profile(SpiConfig::optimal());
        for name in ConfigProfile::STAGE_NAMES {
            assert!(p.stage(name).is_ok(), "{name}");
        }
        let emitted: Vec<&str> = p.stages.iter().map(|s| s.name).collect();
        assert_eq!(emitted, ConfigProfile::STAGE_NAMES);
    }
}
