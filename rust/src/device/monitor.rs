//! PAC1934 energy-monitor model.
//!
//! The paper's board carries two PAC1934 four-channel power monitors
//! sampling each rail at 1024 Hz (§2); all "hardware measurements" in the
//! paper are integrals of those samples. We reproduce the measurement
//! chain: the simulator produces piecewise-constant power segments, the
//! monitor samples them on its own 1/1024 s grid and accumulates
//! `V·I·Δt`. The difference between this sampled integral and the exact
//! one is precisely the kind of few-percent gap the paper reports between
//! hardware measurements and its simulator (2.8% / 2.7%, §5.3).

use crate::device::calib::PAC1934_HZ;
use crate::sim::time::SimTime;
use crate::util::units::{Energy, Power};

/// One monitored power segment: constant `power` over `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Sample-window start.
    pub start: SimTime,
    /// Sample-window end.
    pub end: SimTime,
    /// Power the monitor attributed to the window.
    pub power: Power,
}

/// A sampling energy accumulator for one rail.
#[derive(Debug, Clone)]
pub struct Pac1934 {
    sample_period_ns: u64,
    /// Next sample timestamp (ns).
    next_sample_ns: u64,
    /// Accumulated sampled energy.
    accumulated: Energy,
    /// Number of samples taken.
    samples: u64,
    /// Exact (reference) integral for error reporting.
    exact: Energy,
}

impl Default for Pac1934 {
    fn default() -> Self {
        Self::new(PAC1934_HZ)
    }
}

impl Pac1934 {
    /// A monitor sampling at the given rate.
    pub fn new(sample_rate_hz: f64) -> Pac1934 {
        assert!(sample_rate_hz > 0.0);
        Pac1934 {
            sample_period_ns: (1e9 / sample_rate_hz).round() as u64,
            next_sample_ns: 0,
            accumulated: Energy::ZERO,
            samples: 0,
            exact: Energy::ZERO,
        }
    }

    /// Feed a piecewise-constant segment. Segments must be fed in
    /// non-overlapping, time-ascending order.
    ///
    /// O(1) per segment: the number of sample ticks inside the segment is
    /// computed arithmetically, so multi-hour lifetime simulations (tens
    /// of millions of ticks) cost nothing extra.
    pub fn observe(&mut self, seg: Segment) {
        debug_assert!(seg.end >= seg.start);
        let start = seg.start.nanos();
        let end = seg.end.nanos();
        self.exact += seg.power * seg.end.since(seg.start);
        let period = self.sample_period_ns;
        // Hot-path exit without a division: the pending tick lies at or
        // beyond this segment's end, so no sample falls inside it. This
        // covers the µs-scale phase segments between ~1 ms ticks — the
        // bulk of a DES run. Deferring the gap-skip below is sound
        // because the tick grid is absolute (multiples of the period):
        // advancing past a gap now or at the next covered segment lands
        // the pending tick on the same grid point.
        if self.next_sample_ns >= end {
            return;
        }
        // Advance past any gap before this segment without accumulating
        // (ticks in uncovered gaps measure whatever rail state the caller
        // chose not to report — physically, a segment is always fed).
        if self.next_sample_ns < start {
            let skipped = (start - self.next_sample_ns).div_ceil(period);
            self.next_sample_ns += skipped * period;
            if self.next_sample_ns >= end {
                return;
            }
        }
        // Ticks at next, next+T, ... strictly below end.
        let count = (end - self.next_sample_ns).div_ceil(period);
        self.accumulated += seg.power
            * crate::util::units::Duration::from_nanos((count * period) as f64);
        self.samples += count;
        self.next_sample_ns += count * period;
    }

    /// Energy as the instrument reports it (sampled integral).
    pub fn measured(&self) -> Energy {
        self.accumulated
    }

    /// Exact integral of everything observed (for error analysis).
    pub fn exact(&self) -> Energy {
        self.exact
    }

    /// Relative measurement error vs the exact integral.
    pub fn rel_error(&self) -> f64 {
        if self.exact.joules() == 0.0 {
            0.0
        } else {
            (self.measured().joules() - self.exact.joules()).abs() / self.exact.joules()
        }
    }

    /// Samples accumulated so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Duration;

    fn t(ms: f64) -> SimTime {
        SimTime::ZERO + Duration::from_millis(ms)
    }

    #[test]
    fn constant_power_long_window_converges() {
        let mut m = Pac1934::default();
        m.observe(Segment {
            start: t(0.0),
            end: t(10_000.0), // 10 s
            power: Power::from_milliwatts(134.3),
        });
        // 10 s at 1024 Hz = 10240 samples exactly
        assert_eq!(m.samples(), 10_240);
        assert!(m.rel_error() < 1e-3, "err={}", m.rel_error());
        assert!((m.exact().millijoules() - 1343.0).abs() < 1e-6);
    }

    #[test]
    fn short_burst_between_samples_is_missed() {
        // A 28 µs inference burst (Table 2) fits entirely between two
        // 976 µs sample ticks → the instrument can miss it. This is the
        // physical source of the paper's hardware-vs-simulator gap.
        let mut m = Pac1934::default();
        m.observe(Segment {
            start: t(0.1),
            end: t(0.1281),
            power: Power::from_milliwatts(171.4),
        });
        assert_eq!(m.samples(), 0);
        assert_eq!(m.measured(), Energy::ZERO);
        assert!(m.exact().microjoules() > 4.0);
    }

    #[test]
    fn sampling_error_is_bounded_for_mixed_load() {
        // Alternating config/idle segments like a real run: error stays
        // within a few percent (the paper's 2.8%).
        let mut m = Pac1934::default();
        let mut now = 0.0;
        for _ in 0..200 {
            m.observe(Segment {
                start: t(now),
                end: t(now + 36.145),
                power: Power::from_milliwatts(327.9),
            });
            now += 36.145;
            m.observe(Segment {
                start: t(now),
                end: t(now + 3.855),
                power: Power::from_milliwatts(134.3),
            });
            now += 3.855;
        }
        assert!(m.rel_error() < 0.03, "err={}", m.rel_error());
    }

    #[test]
    fn zero_duration_segment_is_noop() {
        let mut m = Pac1934::default();
        m.observe(Segment {
            start: t(1.0),
            end: t(1.0),
            power: Power::from_milliwatts(100.0),
        });
        assert_eq!(m.measured(), Energy::ZERO);
        assert_eq!(m.exact(), Energy::ZERO);
    }

    #[test]
    fn custom_sample_rate() {
        let mut m = Pac1934::new(10.0); // 10 Hz
        m.observe(Segment {
            start: t(0.0),
            end: t(1000.0),
            power: Power::from_watts(1.0),
        });
        assert_eq!(m.samples(), 10);
        assert!((m.measured().joules() - 1.0).abs() < 1e-9);
    }
}
