//! Board composition: the full heterogeneous platform of paper Fig 3.
//!
//! Bundles the FPGA, flash, MCU, battery and per-rail PAC1934 monitors
//! into one object the strategy simulations and the serving coordinator
//! drive. Energy accounting follows the paper: the battery budget is
//! charged with *FPGA-side* energy (FPGA + clock ref + flash — what the
//! paper measures), while MCU energy is tracked separately for reporting.

use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::battery::{Battery, Exhausted};
use crate::device::bitstream::Bitstream;
use crate::device::flash::{Flash, StoredImage};
use crate::device::fpga::{Fpga, FpgaError};
use crate::device::mcu::Mcu;
use crate::device::monitor::{Pac1934, Segment};
use crate::device::rails::PowerSaving;
use crate::sim::time::SimTime;
use crate::util::units::{Duration, Energy, Power};

/// The paper's LSTM image, stored once per `(model, compressed)` combo.
///
/// Synthesizing the bitstream and walking its ~1333 frames for the
/// compression ratio is by far the most expensive part of building a
/// board; sweeps build one board per cell, so without this cache the
/// sweep engine spent more time re-deriving an identical image than
/// simulating. The cache is tiny (≤ 4 entries) and the images are
/// immutable, so sharing is safe.
fn lstm_image(model: FpgaModel, compressed: bool) -> Arc<StoredImage> {
    type Key = (FpgaModel, bool);
    static CACHE: Lazy<Mutex<Vec<(Key, Arc<StoredImage>)>>> = Lazy::new(|| Mutex::new(Vec::new()));
    let mut cache = CACHE.lock().expect("image cache poisoned");
    if let Some((_, image)) = cache.iter().find(|(k, _)| *k == (model, compressed)) {
        return image.clone();
    }
    let image = Arc::new(StoredImage::new(
        Bitstream::lstm_accelerator(model),
        compressed,
    ));
    cache.push(((model, compressed), image.clone()));
    image
}

/// Why a board operation failed.
#[derive(Debug, thiserror::Error)]
pub enum BoardError {
    /// The FPGA refused the operation in its current state.
    #[error(transparent)]
    Fpga(#[from] FpgaError),
    /// The battery budget is exhausted.
    #[error(transparent)]
    Exhausted(#[from] Exhausted),
    /// Every configuration attempt the retry policy allows has faulted;
    /// the device gives up on this request and stays powered off. The
    /// payload is the number of attempts made. Recoverable at the
    /// coordinator layer (shed/re-route), unlike `Exhausted`.
    #[error("configuration gave up after {0} faulted attempts")]
    RetriesExhausted(u32),
}

/// The assembled platform.
#[derive(Debug, Clone)]
pub struct Board {
    /// The Spartan-7 device.
    pub fpga: Fpga,
    /// The configuration flash.
    pub flash: Flash,
    /// The RP2040 coordinator.
    pub mcu: Mcu,
    /// The energy budget.
    pub battery: Battery,
    /// Aggregate FPGA-side monitor (the "hardware measurement" channel).
    pub monitor: Pac1934,
    /// Wall-clock of the board's own accounting (advanced by the driver).
    pub now: SimTime,
    /// Exact FPGA-side energy (reference for the monitor's sampled value).
    pub fpga_energy: Energy,
}

impl Board {
    /// A board with the paper's LSTM accelerator programmed into flash.
    pub fn paper_setup(model: FpgaModel, compressed: bool) -> Board {
        let mut flash = Flash::new();
        flash.program_shared("lstm", lstm_image(model, compressed));
        Board {
            fpga: Fpga::new(model),
            flash,
            mcu: Mcu::new(),
            battery: Battery::paper_budget(),
            monitor: Pac1934::default(),
            now: SimTime::ZERO,
            fpga_energy: Energy::ZERO,
        }
    }

    /// Return the board to its pristine `paper_setup` state — full
    /// battery, cold FPGA, zeroed ledgers and monitor — while keeping the
    /// programmed flash (and its shared bitstream images) intact. Sweep
    /// cells reuse one board through this instead of rebuilding; a reset
    /// board is state-for-state identical to a fresh `paper_setup`.
    pub fn reset(&mut self) {
        self.fpga = Fpga::new(self.fpga.model);
        self.mcu = Mcu::new();
        self.battery = Battery::paper_budget();
        self.monitor = Pac1934::default();
        self.now = SimTime::ZERO;
        self.fpga_energy = Energy::ZERO;
    }

    /// Advance time by `dur` with the FPGA-side rails drawing `power`,
    /// charging the battery budget and feeding the monitor.
    pub fn spend(&mut self, power: Power, dur: Duration) -> Result<(), BoardError> {
        let end = self.now + dur;
        self.battery.try_draw_power(power, dur)?;
        self.monitor.observe(Segment {
            start: self.now,
            end,
            power,
        });
        self.fpga_energy += power * dur;
        self.now = end;
        Ok(())
    }

    /// Charge an instantaneous energy transient (capacitor inrush) to the
    /// budget; no time passes and the 1024 Hz monitor cannot see it.
    pub fn spend_transient(&mut self, energy: Energy) -> Result<(), BoardError> {
        self.battery.try_draw(energy)?;
        self.fpga_energy += energy;
        Ok(())
    }

    /// Power-cycle + configure from flash: the full On-Off per-request
    /// preamble. Charges the inrush transient and every configuration
    /// stage. Returns the configuration-phase duration.
    pub fn power_on_and_configure(
        &mut self,
        slot: &str,
        spi: SpiConfig,
    ) -> Result<Duration, BoardError> {
        let inrush = self.fpga.power_on();
        self.spend_transient(inrush)?;
        let profile = self.fpga.configure(&self.flash, slot, spi)?;
        for stage in &profile.stages {
            self.spend(stage.power, stage.time)?;
        }
        Ok(profile.total_time())
    }

    /// Execute the three active phases of a workload item (data loading,
    /// inference, data offloading) with the given phase powers/durations.
    pub fn run_item_phases(
        &mut self,
        phases: &[(Power, Duration)],
    ) -> Result<Duration, BoardError> {
        self.fpga.begin_work()?;
        let mut total = Duration::ZERO;
        for &(power, time) in phases {
            self.spend(power, time)?;
            total += time;
        }
        self.fpga.finish_work()?;
        Ok(total)
    }

    /// Idle at the Table 3 power for `saving` over `dur`.
    pub fn idle_for(&mut self, saving: PowerSaving, dur: Duration) -> Result<(), BoardError> {
        self.fpga.enter_idle(saving)?;
        self.spend(Fpga::idle_power(saving), dur)
    }

    /// Power the FPGA off and let time pass with only the flash floor.
    ///
    /// NOTE on paper fidelity: the paper's On-Off model says "the FPGA
    /// does not use energy while powered off"; the flash floor exists on
    /// the real board but the paper folds it out of the off-state. We
    /// follow the paper by default (`charge_flash_floor = false`) and
    /// expose the physical variant for sensitivity analysis.
    pub fn off_for(&mut self, dur: Duration, charge_flash_floor: bool) -> Result<(), BoardError> {
        self.fpga.power_off();
        let power = if charge_flash_floor {
            self.fpga.static_power() // 15.2 mW flash floor
        } else {
            Power::ZERO
        };
        self.spend(power, dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table2_phases() -> Vec<(Power, Duration)> {
        vec![
            (Power::from_milliwatts(138.7), Duration::from_millis(0.0100)),
            (Power::from_milliwatts(171.4), Duration::from_millis(0.0281)),
            (Power::from_milliwatts(144.1), Duration::from_millis(0.0020)),
        ]
    }

    #[test]
    fn one_onoff_item_costs_the_calibrated_energy() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        let cfg_time = board
            .power_on_and_configure("lstm", SpiConfig::optimal())
            .unwrap();
        assert!((cfg_time.millis() - 36.145).abs() < 0.01);
        board.run_item_phases(&table2_phases()).unwrap();
        // 11.85 (config) + 0.1244 (inrush) + 0.0065 (phases) ≈ 11.98 mJ
        assert!(
            (board.fpga_energy.millijoules() - 11.983).abs() < 0.01,
            "E={}",
            board.fpga_energy.millijoules()
        );
    }

    #[test]
    fn idle_waiting_item_is_far_cheaper() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        board
            .power_on_and_configure("lstm", SpiConfig::optimal())
            .unwrap();
        let after_init = board.fpga_energy;
        board.run_item_phases(&table2_phases()).unwrap();
        board
            .idle_for(PowerSaving::BASELINE, Duration::from_millis(39.96))
            .unwrap();
        let per_item = board.fpga_energy - after_init;
        // 0.0065 mJ phases + 134.3 mW × 39.96 ms ≈ 5.373 mJ (vs 11.98)
        assert!((per_item.millijoules() - 5.373).abs() < 0.01, "{}", per_item.millijoules());
    }

    #[test]
    fn budget_exhaustion_stops_spending() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        // Drain almost everything
        board
            .spend(Power::from_watts(1.0), Duration::from_secs(4146.9))
            .unwrap();
        let err = board.spend(Power::from_watts(1.0), Duration::from_secs(1.0));
        assert!(matches!(err, Err(BoardError::Exhausted(_))));
    }

    #[test]
    fn off_state_follows_paper_by_default() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        board
            .power_on_and_configure("lstm", SpiConfig::optimal())
            .unwrap();
        let before = board.fpga_energy;
        board.off_for(Duration::from_secs(1.0), false).unwrap();
        assert_eq!(board.fpga_energy, before, "paper: off = zero energy");
        board.power_on_and_configure("lstm", SpiConfig::optimal()).unwrap();
        let before2 = board.fpga_energy;
        board.off_for(Duration::from_secs(1.0), true).unwrap();
        assert!((board.fpga_energy - before2).millijoules() - 15.2 < 1e-6);
    }

    #[test]
    fn monitor_tracks_board_within_sampling_error() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        for _ in 0..50 {
            board
                .power_on_and_configure("lstm", SpiConfig::optimal())
                .unwrap();
            board.run_item_phases(&table2_phases()).unwrap();
            board.off_for(Duration::from_millis(3.8), false).unwrap();
        }
        let exact = board.monitor.exact().joules();
        let measured = board.monitor.measured().joules();
        assert!((measured - exact).abs() / exact < 0.05);
    }

    #[test]
    fn mcu_side_accounting_is_separate() {
        let mut board = Board::paper_setup(FpgaModel::Xc7s15, true);
        board.mcu.coordinate_request(Duration::from_millis(1.0));
        assert_eq!(board.fpga_energy, Energy::ZERO);
        assert!(board.mcu.energy.microjoules() > 0.0);
        assert_eq!(board.battery.drawn(), Energy::ZERO);
    }
}
