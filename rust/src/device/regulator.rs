//! Voltage-regulator model with retention-mode undervolting (Method 2).
//!
//! The paper's Method 2 lowers VCCINT 1.0→0.75 V and VCCAUX 1.8→1.5 V
//! during idle — enough to retain configuration SRAM state but below the
//! operational minimum. The authors' own hardware lacked dynamic voltage
//! scaling, so they simulated it; we model a regulator whose static-load
//! power scales as `(V/V_nom)^k` (leakage-dominated, k = 3, fitted so the
//! combined Table 3 idle power lands on 24.0 mW — DESIGN.md §6).

use crate::device::calib::LEAKAGE_EXP;
use crate::util::units::{Power, Voltage};

/// Regulator operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegMode {
    /// Rail off (FPGA powered down).
    Off,
    /// Nominal operating voltage.
    Nominal,
    /// Retention voltage: state held, logic non-operational (Method 2).
    Retention,
}

/// One adjustable regulator feeding an FPGA supply rail.
#[derive(Debug, Clone, PartialEq)]
pub struct Regulator {
    /// Rail name (VCCINT/VCCAUX).
    pub name: &'static str,
    /// Nominal operating voltage.
    pub nominal: Voltage,
    /// Method 2 retention voltage.
    pub retention: Voltage,
    /// Static power drawn by the load at nominal voltage.
    pub static_load_nom: Power,
    /// Current regulator mode.
    pub mode: RegMode,
}

impl Regulator {
    /// A regulator with the given voltages and static draw, starting off.
    pub fn new(
        name: &'static str,
        nominal: Voltage,
        retention: Voltage,
        static_load_nom: Power,
    ) -> Regulator {
        assert!(retention.volts() <= nominal.volts());
        Regulator {
            name,
            nominal,
            retention,
            static_load_nom,
            mode: RegMode::Off,
        }
    }

    /// Output voltage in the current mode.
    pub fn voltage(&self) -> Voltage {
        match self.mode {
            RegMode::Off => Voltage::from_volts(0.0),
            RegMode::Nominal => self.nominal,
            RegMode::Retention => self.retention,
        }
    }

    /// Static load power in the current mode: `P_nom · (V/V_nom)^k`.
    pub fn static_power(&self) -> Power {
        match self.mode {
            RegMode::Off => Power::ZERO,
            RegMode::Nominal => self.static_load_nom,
            RegMode::Retention => {
                let scale =
                    (self.retention.volts() / self.nominal.volts()).powf(LEAKAGE_EXP);
                self.static_load_nom * scale
            }
        }
    }

    /// Whether the FPGA can operate (transmit data / run inference) at the
    /// rail's current voltage. Retention holds state only.
    pub fn operational(&self) -> bool {
        self.mode == RegMode::Nominal
    }

    /// Whether configuration SRAM state survives the current mode.
    pub fn retains_state(&self) -> bool {
        self.mode != RegMode::Off
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::calib::{
        VCCAUX_NOM, VCCAUX_RETENTION, VCCAUX_STATIC_NOM, VCCINT_NOM, VCCINT_RETENTION,
        VCCINT_STATIC_NOM,
    };

    fn vccint() -> Regulator {
        Regulator::new("VCCINT", VCCINT_NOM, VCCINT_RETENTION, VCCINT_STATIC_NOM)
    }

    fn vccaux() -> Regulator {
        Regulator::new("VCCAUX", VCCAUX_NOM, VCCAUX_RETENTION, VCCAUX_STATIC_NOM)
    }

    #[test]
    fn off_mode_draws_nothing_and_loses_state() {
        let r = vccint();
        assert_eq!(r.static_power(), Power::ZERO);
        assert!(!r.retains_state());
        assert!(!r.operational());
    }

    #[test]
    fn nominal_mode_draws_nominal() {
        let mut r = vccint();
        r.mode = RegMode::Nominal;
        assert_eq!(r.static_power(), VCCINT_STATIC_NOM);
        assert!(r.operational());
        assert!(r.retains_state());
    }

    #[test]
    fn retention_scales_cubically_and_keeps_state() {
        let mut r = vccint();
        r.mode = RegMode::Retention;
        let expected = VCCINT_STATIC_NOM.milliwatts() * (0.75f64).powi(3);
        assert!((r.static_power().milliwatts() - expected).abs() < 1e-9);
        assert!(!r.operational());
        assert!(r.retains_state());
        assert_eq!(r.voltage(), VCCINT_RETENTION);
    }

    #[test]
    fn both_rails_in_retention_hit_table3() {
        // VCCINT + VCCAUX retention static + flash floor = 24.0 mW
        let mut int = vccint();
        let mut aux = vccaux();
        int.mode = RegMode::Retention;
        aux.mode = RegMode::Retention;
        let total = int.static_power()
            + aux.static_power()
            + crate::device::calib::FLASH_STANDBY_POWER;
        assert!((total.milliwatts() - 24.0).abs() < 0.05, "{}", total.milliwatts());
    }

    #[test]
    #[should_panic]
    fn retention_above_nominal_rejected() {
        Regulator::new(
            "bad",
            Voltage::from_volts(1.0),
            Voltage::from_volts(1.2),
            Power::ZERO,
        );
    }
}
