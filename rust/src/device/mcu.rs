//! RP2040 MCU model.
//!
//! The MCU's role in the paper's system (§2) is coordination: it sleeps at
//! 180 µA, wakes on a timer when enough sensor data has accumulated,
//! issues an inference request to the FPGA over SPI, collects the result
//! and goes back to sleep. Its energy lives on its own rail and is *not*
//! part of the paper's FPGA-side budget accounting; we model it so the
//! serving coordinator has a faithful request source and so whole-board
//! energy can be reported alongside the paper's FPGA-only numbers.

use crate::device::calib::{MCU_ACTIVE_POWER, MCU_RAIL, MCU_SLEEP_CURRENT_UA};
use crate::util::units::{Current, Duration, Energy, Power};

/// MCU operating state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McuState {
    /// Low-power sleep between requests (180 µA).
    Sleep,
    /// Awake handling a request (SPI transfers, bookkeeping).
    Active,
}

/// The RP2040 coordinator MCU.
#[derive(Debug, Clone)]
pub struct Mcu {
    /// Current operating state.
    pub state: McuState,
    /// Cumulative energy on the MCU rail.
    pub energy: Energy,
    /// Cumulative time spent awake.
    pub active_time: Duration,
    /// Requests issued so far.
    pub requests_issued: u64,
}

impl Default for Mcu {
    fn default() -> Self {
        Self::new()
    }
}

impl Mcu {
    /// A sleeping MCU.
    pub fn new() -> Mcu {
        Mcu {
            state: McuState::Sleep,
            energy: Energy::ZERO,
            active_time: Duration::ZERO,
            requests_issued: 0,
        }
    }

    /// Sleep-state draw (paper §2: 180 µA at 3.3 V).
    pub fn sleep_power() -> Power {
        MCU_RAIL * Current::from_microamps(MCU_SLEEP_CURRENT_UA)
    }

    /// Active draw while coordinating a request.
    pub fn active_power() -> Power {
        MCU_ACTIVE_POWER
    }

    /// Account a sleeping interval.
    pub fn sleep_for(&mut self, dur: Duration) {
        debug_assert!(self.state == McuState::Sleep);
        self.energy += Self::sleep_power() * dur;
    }

    /// Wake, coordinate one request for `dur`, and return to sleep.
    /// Returns the energy spent awake.
    pub fn coordinate_request(&mut self, dur: Duration) -> Energy {
        self.state = McuState::Active;
        let e = Self::active_power() * dur;
        self.energy += e;
        self.active_time += dur;
        self.requests_issued += 1;
        self.state = McuState::Sleep;
        e
    }

    /// Duty-cycle estimate: mean MCU power for a request period where the
    /// MCU is awake `active` per period and asleep otherwise.
    pub fn mean_power(period: Duration, active: Duration) -> Power {
        debug_assert!(active.secs() <= period.secs());
        let e = Self::active_power() * active + Self::sleep_power() * (period - active);
        e / period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sleep_power_is_180ua_at_3v3() {
        assert!((Mcu::sleep_power().milliwatts() - 0.594).abs() < 1e-9);
    }

    #[test]
    fn request_accounting() {
        let mut mcu = Mcu::new();
        let e = mcu.coordinate_request(Duration::from_millis(1.0));
        assert!((e.microjoules() - 66.0).abs() < 1e-9);
        assert_eq!(mcu.requests_issued, 1);
        assert_eq!(mcu.state, McuState::Sleep);
    }

    #[test]
    fn sleep_accumulates() {
        let mut mcu = Mcu::new();
        mcu.sleep_for(Duration::from_secs(1.0));
        assert!((mcu.energy.microjoules() - 594.0).abs() < 1e-9);
    }

    #[test]
    fn mean_power_between_sleep_and_active() {
        let p = Mcu::mean_power(Duration::from_millis(40.0), Duration::from_millis(1.0));
        assert!(p > Mcu::sleep_power());
        assert!(p < Mcu::active_power());
        // 1/40 duty: ≈ 0.594·(39/40) + 66·(1/40) ≈ 2.229 mW
        assert!((p.milliwatts() - 2.229).abs() < 0.01, "{}", p.milliwatts());
    }

    #[test]
    fn mcu_energy_is_negligible_vs_fpga_item() {
        // Sanity: the paper ignores MCU energy in the FPGA budget; one
        // sleeping 40 ms period costs ~24 µJ vs the 11,983 µJ On-Off item.
        let per_period = Mcu::sleep_power() * Duration::from_millis(40.0);
        assert!(per_period.microjoules() < 25.0);
    }
}
