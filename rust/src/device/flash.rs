//! SPI NOR flash model.
//!
//! Stores configuration bitstreams (slot per accelerator) and exposes the
//! read-side constraints of the paper's part: 3–66 MHz clock, ×1/×2/×4
//! buswidths. Its standby draw (≈15.2 mW) is the idle-power floor the
//! paper's §5.4 identifies as the remaining hardware constraint; its
//! *active* read power during bitstream loading is part of the fitted
//! loading-stage power in `device::spi`, not double-counted here.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::schema::SpiConfig;
use crate::device::bitstream::Bitstream;
use crate::device::calib::FLASH_STANDBY_POWER;
use crate::device::compression::{compress, stream_bits};
use crate::util::units::Power;

/// Why a flash read failed.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FlashError {
    /// No image programmed at the requested slot.
    #[error("no bitstream stored in slot '{0}'")]
    EmptySlot(String),
    /// The requested link parameters exceed the part's limits.
    #[error("spi setting unsupported by flash: {0}")]
    Unsupported(String),
}

/// A stored image: the bitstream plus whether it was written compressed.
///
/// The on-wire size is computed once at construction: the frame-dedup
/// compressor walks all ~1333 frames, and On-Off workloads reconfigure
/// per request — recompressing per configuration made On-Off DES items
/// ~500× slower than Idle-Waiting ones (§Perf log in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct StoredImage {
    /// The stored bitstream.
    pub bitstream: Bitstream,
    /// Whether it is stored MFWR-compressed.
    pub compressed: bool,
    cached_stream_bits: u64,
}

impl StoredImage {
    /// Wrap a bitstream for storage.
    pub fn new(bitstream: Bitstream, compressed: bool) -> StoredImage {
        let cached_stream_bits = stream_bits(&bitstream, compressed);
        StoredImage {
            bitstream,
            compressed,
            cached_stream_bits,
        }
    }

    /// Bits that will cross the SPI link when this image is loaded.
    #[inline]
    pub fn stream_bits(&self) -> u64 {
        self.cached_stream_bits
    }
}

/// The flash chip: bitstream slots + electrical limits.
///
/// Slots hold [`Arc`]-shared images: sweeps build (and clone) thousands
/// of boards per run, and sharing the stored bitstream makes a board
/// clone a refcount bump instead of a multi-megabit frame copy.
#[derive(Debug, Clone)]
pub struct Flash {
    slots: BTreeMap<String, Arc<StoredImage>>,
    /// Standby draw while the board is powered (the §5.4 floor).
    pub standby_power: Power,
    /// Maximum supported SPI clock.
    pub max_freq_mhz: f64,
    /// Supported bus widths.
    pub supported_widths: [u8; 3],
}

impl Default for Flash {
    fn default() -> Self {
        Flash::new()
    }
}

impl Flash {
    /// An empty flash with datasheet link limits.
    pub fn new() -> Flash {
        Flash {
            slots: BTreeMap::new(),
            standby_power: FLASH_STANDBY_POWER,
            max_freq_mhz: 66.0,
            supported_widths: [1, 2, 4],
        }
    }

    /// Program a bitstream into a named slot (build-time operation; not on
    /// the energy-accounted request path).
    pub fn program(&mut self, slot: impl Into<String>, bitstream: Bitstream, compressed: bool) {
        self.program_shared(slot, Arc::new(StoredImage::new(bitstream, compressed)));
    }

    /// Program an already-stored (and possibly shared) image into a named
    /// slot. The fast path for sweeps: the image — including its
    /// compression walk — is built once and shared by every board clone.
    pub fn program_shared(&mut self, slot: impl Into<String>, image: Arc<StoredImage>) {
        self.slots.insert(slot.into(), image);
    }

    /// Validate an SPI setting against the chip's limits.
    pub fn check_spi(&self, spi: &SpiConfig) -> Result<(), FlashError> {
        if !self.supported_widths.contains(&spi.buswidth) {
            return Err(FlashError::Unsupported(format!(
                "buswidth {}",
                spi.buswidth
            )));
        }
        if spi.freq_mhz < 3.0 || spi.freq_mhz > self.max_freq_mhz {
            return Err(FlashError::Unsupported(format!(
                "freq {} MHz",
                spi.freq_mhz
            )));
        }
        Ok(())
    }

    /// Fetch a stored image for configuration.
    pub fn image(&self, slot: &str) -> Result<&StoredImage, FlashError> {
        self.slots
            .get(slot)
            .map(|a| a.as_ref())
            .ok_or_else(|| FlashError::EmptySlot(slot.to_string()))
    }

    /// Names of the programmed image slots.
    pub fn slots(&self) -> impl Iterator<Item = &str> {
        self.slots.keys().map(|s| s.as_str())
    }

    /// Report the on-flash compression ratio of a slot (1.0 if stored raw).
    pub fn compression_ratio(&self, slot: &str) -> Result<f64, FlashError> {
        let image = self.image(slot)?;
        Ok(if image.compressed {
            compress(&image.bitstream).ratio()
        } else {
            1.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::FpgaModel;

    fn flash_with_lstm(compressed: bool) -> Flash {
        let mut f = Flash::new();
        f.program(
            "lstm",
            Bitstream::lstm_accelerator(FpgaModel::Xc7s15),
            compressed,
        );
        f
    }

    #[test]
    fn standby_power_is_the_papers_floor() {
        assert!((Flash::new().standby_power.milliwatts() - 15.2).abs() < 1e-9);
    }

    #[test]
    fn program_and_fetch() {
        let f = flash_with_lstm(true);
        let img = f.image("lstm").unwrap();
        assert!(img.compressed);
        assert_eq!(f.slots().collect::<Vec<_>>(), vec!["lstm"]);
    }

    #[test]
    fn empty_slot_errors() {
        let f = Flash::new();
        assert!(matches!(f.image("nope"), Err(FlashError::EmptySlot(_))));
    }

    #[test]
    fn stream_bits_depend_on_compression() {
        let raw = flash_with_lstm(false).image("lstm").unwrap().stream_bits();
        let comp = flash_with_lstm(true).image("lstm").unwrap().stream_bits();
        assert!(comp < raw);
        assert_eq!(raw, FpgaModel::Xc7s15.bitstream_bits());
    }

    #[test]
    fn spi_limits_enforced() {
        let f = Flash::new();
        assert!(f.check_spi(&SpiConfig::optimal()).is_ok());
        assert!(f
            .check_spi(&SpiConfig {
                buswidth: 8,
                freq_mhz: 33.0,
                compressed: false
            })
            .is_err());
        assert!(f
            .check_spi(&SpiConfig {
                buswidth: 4,
                freq_mhz: 80.0,
                compressed: false
            })
            .is_err());
        assert!(f
            .check_spi(&SpiConfig {
                buswidth: 4,
                freq_mhz: 1.0,
                compressed: false
            })
            .is_err());
    }

    #[test]
    fn compression_ratio_reporting() {
        assert_eq!(flash_with_lstm(false).compression_ratio("lstm").unwrap(), 1.0);
        let r = flash_with_lstm(true).compression_ratio("lstm").unwrap();
        assert!((r - 1.826).abs() < 0.01);
    }
}
