//! Synthetic configuration bitstreams.
//!
//! The paper generates real Vivado bitstreams for its LSTM accelerator; we
//! cannot, so this module synthesizes a *structurally faithful* stand-in:
//! a header plus a sequence of 7-series configuration frames (101×32-bit
//! words, UG470), of which a design-dependent subset is "occupied"
//! (incompressible pseudo-random content) and the rest are empty (all
//! zeros). The frame-dedup compressor in [`crate::device::compression`]
//! then produces compression ratios that emerge from the same mechanism
//! the 7-series compressed-bitstream option uses (multi-frame writes for
//! identical frames), rather than from a hardcoded ratio.
//!
//! Occupancy for the paper's LSTM h=20 design is calibrated in
//! `device::calib` so that loading times reproduce Fig 7 / §5.2.

use crate::config::schema::FpgaModel;
use crate::device::calib::{design_occupied_frames, FRAME_BITS};
use crate::util::rng::Xoshiro256ss;

/// One configuration frame: occupied frames carry a content hash standing
/// in for their 3232 bits of data; empty frames are all-zero. We store a
/// 64-bit digest, not the raw words — the simulator only needs identity
/// (for dedup) and size (for transfer timing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Frame {
    /// A frame containing no design content (compressible).
    Empty,
    /// A frame with design content, identified by digest.
    Occupied { digest: u64 },
}

impl Frame {
    /// Frame payload size in bits.
    pub fn bits(&self) -> u64 {
        FRAME_BITS
    }

    /// True for an empty (dedupable) frame.
    pub fn is_empty(&self) -> bool {
        matches!(self, Frame::Empty)
    }
}

/// A synthetic bitstream: header + frames.
#[derive(Debug, Clone)]
pub struct Bitstream {
    /// Device this bitstream targets.
    pub model: FpgaModel,
    /// Header/command overhead bits before frame data.
    pub header_bits: u64,
    /// The configuration frames, in address order.
    pub frames: Vec<Frame>,
}

impl Bitstream {
    /// Synthesize the bitstream for a design with `occupied` non-empty
    /// frames on `model`, deterministically from `seed`.
    ///
    /// The frame count and header size are derived from the device's total
    /// bitstream length (UG470 Table 1-1): `frames = floor(bits / 3232)`,
    /// remainder becomes the header (sync word, command writes).
    pub fn synthesize(model: FpgaModel, occupied: u64, seed: u64) -> Bitstream {
        let total_bits = model.bitstream_bits();
        let n_frames = total_bits / FRAME_BITS;
        let header_bits = total_bits - n_frames * FRAME_BITS;
        assert!(
            occupied <= n_frames,
            "design occupies {occupied} frames but {model} only has {n_frames}"
        );
        // Spread occupied frames deterministically across the address space
        // (real designs cluster by clock region; for dedup only the counts
        // matter, but spreading exercises the compressor's run handling).
        let mut rng = Xoshiro256ss::new(seed ^ 0xB175_7EA4);
        let mut index: Vec<u64> = (0..n_frames).collect();
        rng.shuffle(&mut index);
        let occupied_set: std::collections::HashSet<u64> =
            index.into_iter().take(occupied as usize).collect();
        let frames = (0..n_frames)
            .map(|i| {
                if occupied_set.contains(&i) {
                    // unique digest per frame → incompressible by dedup
                    Frame::Occupied {
                        digest: rng.next_u64_raw() | 1,
                    }
                } else {
                    Frame::Empty
                }
            })
            .collect();
        Bitstream {
            model,
            header_bits,
            frames,
        }
    }

    /// The paper's LSTM hidden-size-20 accelerator bitstream for `model`.
    pub fn lstm_accelerator(model: FpgaModel) -> Bitstream {
        Bitstream::synthesize(model, design_occupied_frames(model), 0x15D4)
    }

    /// Total (uncompressed) length in bits — matches UG470 for the device.
    pub fn total_bits(&self) -> u64 {
        self.header_bits + self.frames.len() as u64 * FRAME_BITS
    }

    /// Total frame count.
    pub fn n_frames(&self) -> usize {
        self.frames.len()
    }

    /// Frames carrying design content.
    pub fn occupied_frames(&self) -> usize {
        self.frames.iter().filter(|f| !f.is_empty()).count()
    }

    /// Fraction of frames carrying design content.
    pub fn occupancy(&self) -> f64 {
        self.occupied_frames() as f64 / self.n_frames() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_bits_matches_ug470() {
        for model in [FpgaModel::Xc7s15, FpgaModel::Xc7s25] {
            let bs = Bitstream::lstm_accelerator(model);
            assert_eq!(bs.total_bits(), model.bitstream_bits());
        }
    }

    #[test]
    fn frame_counts() {
        let bs15 = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        assert_eq!(bs15.n_frames(), (4_310_752 / 3232) as usize); // 1333
        assert_eq!(bs15.occupied_frames(), 704);
        let bs25 = Bitstream::lstm_accelerator(FpgaModel::Xc7s25);
        assert_eq!(bs25.n_frames(), (9_934_432 / 3232) as usize); // 3073
        assert_eq!(bs25.occupied_frames(), 794);
    }

    #[test]
    fn synthesis_is_deterministic() {
        let a = Bitstream::synthesize(FpgaModel::Xc7s15, 100, 7);
        let b = Bitstream::synthesize(FpgaModel::Xc7s15, 100, 7);
        assert_eq!(a.frames, b.frames);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Bitstream::synthesize(FpgaModel::Xc7s15, 100, 7);
        let b = Bitstream::synthesize(FpgaModel::Xc7s15, 100, 8);
        assert_ne!(a.frames, b.frames);
    }

    #[test]
    fn occupied_digests_are_unique() {
        let bs = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        let mut digests: Vec<u64> = bs
            .frames
            .iter()
            .filter_map(|f| match f {
                Frame::Occupied { digest } => Some(*digest),
                Frame::Empty => None,
            })
            .collect();
        let n = digests.len();
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), n, "digest collision would break dedup stats");
    }

    #[test]
    #[should_panic(expected = "only has")]
    fn over_occupancy_panics() {
        Bitstream::synthesize(FpgaModel::Xc7s15, 10_000, 0);
    }

    #[test]
    fn occupancy_fraction() {
        let bs = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        assert!((bs.occupancy() - 704.0 / 1333.0).abs() < 1e-12);
    }
}
