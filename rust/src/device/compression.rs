//! Frame-deduplicating bitstream compression.
//!
//! 7-series "compressed bitstream" (the `BITSTREAM.GENERAL.COMPRESS`
//! option the paper toggles) works by detecting identical configuration
//! frames and replacing repeats with multi-frame-write (MFWR) commands:
//! the frame data is transmitted once, then each additional identical
//! frame costs only a short command sequence. For sparse designs most
//! frames are all-zero, so the dominant saving is collapsing the empty
//! frames onto a single transmitted zero-frame.
//!
//! This module implements exactly that mechanism over the synthetic
//! [`Bitstream`]; compression *ratios are an output*, not an input — the
//! paper-matching loading times in Experiment 1 emerge from the frame
//! occupancy calibrated in `device::calib`.

use std::collections::HashMap;

use crate::device::bitstream::{Bitstream, Frame};
use crate::device::calib::{FRAME_BITS, MFWR_CMD_BITS};

/// Result of compressing a bitstream.
#[derive(Debug, Clone, PartialEq)]
pub struct Compressed {
    /// Bits that must be shifted in through the configuration port.
    pub bits: u64,
    /// Frames whose data was transmitted in full (unique contents).
    pub unique_frames: u64,
    /// Frames replaced by MFWR command sequences.
    pub mfwr_frames: u64,
    /// Uncompressed size for ratio computation.
    pub original_bits: u64,
}

impl Compressed {
    /// Compression ratio (original / compressed), ≥ 1 whenever dedup wins.
    pub fn ratio(&self) -> f64 {
        self.original_bits as f64 / self.bits as f64
    }
}

/// Compress by frame dedup: first occurrence of each distinct frame is
/// transmitted in full; every repeat costs `MFWR_CMD_BITS`.
pub fn compress(bs: &Bitstream) -> Compressed {
    let mut seen: HashMap<Frame, ()> = HashMap::with_capacity(bs.frames.len());
    let mut unique = 0u64;
    let mut mfwr = 0u64;
    for frame in &bs.frames {
        if seen.insert(*frame, ()).is_none() {
            unique += 1;
        } else {
            mfwr += 1;
        }
    }
    Compressed {
        bits: bs.header_bits + unique * FRAME_BITS + mfwr * MFWR_CMD_BITS,
        unique_frames: unique,
        mfwr_frames: mfwr,
        original_bits: bs.total_bits(),
    }
}

/// Size in bits actually shifted through the config port for the given
/// compression setting.
pub fn stream_bits(bs: &Bitstream, compressed: bool) -> u64 {
    if compressed {
        compress(bs).bits
    } else {
        bs.total_bits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::FpgaModel;

    #[test]
    fn compression_never_larger_when_any_dup_exists() {
        let bs = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        let c = compress(&bs);
        assert!(c.bits < c.original_bits);
        assert!(c.ratio() > 1.0);
    }

    #[test]
    fn lstm_on_xc7s15_ratio_matches_fit() {
        // DESIGN.md §6: compressed ≈ 2.361 Mb, ratio ≈ 1.83×
        let bs = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        let c = compress(&bs);
        // 704 occupied (unique) + 1 zero-frame transmitted + 628 MFWR
        assert_eq!(c.unique_frames, 705);
        assert_eq!(c.mfwr_frames, 1333 - 705);
        let expected = bs.header_bits + 705 * FRAME_BITS + (1333 - 705) * MFWR_CMD_BITS;
        assert_eq!(c.bits, expected);
        assert!((c.ratio() - 1.826).abs() < 0.01, "ratio={}", c.ratio());
    }

    #[test]
    fn lstm_on_xc7s25_compresses_harder() {
        // same design on a bigger die → more empty frames → higher ratio
        let c15 = compress(&Bitstream::lstm_accelerator(FpgaModel::Xc7s15));
        let c25 = compress(&Bitstream::lstm_accelerator(FpgaModel::Xc7s25));
        assert!(c25.ratio() > c15.ratio());
        assert!((c25.ratio() - 3.47).abs() < 0.05, "ratio={}", c25.ratio());
    }

    #[test]
    fn fully_occupied_design_barely_compresses() {
        let bs = Bitstream::synthesize(FpgaModel::Xc7s15, 1333, 3);
        let c = compress(&bs);
        // all frames unique → only the (nonexistent) dup saving; equal size
        assert_eq!(c.bits, c.original_bits);
        assert_eq!(c.mfwr_frames, 0);
    }

    #[test]
    fn empty_design_compresses_maximally() {
        let bs = Bitstream::synthesize(FpgaModel::Xc7s15, 0, 3);
        let c = compress(&bs);
        assert_eq!(c.unique_frames, 1); // single zero frame
        assert_eq!(c.mfwr_frames, 1332);
        assert!(c.ratio() > 20.0);
    }

    #[test]
    fn stream_bits_respects_flag() {
        let bs = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
        assert_eq!(stream_bits(&bs, false), bs.total_bits());
        assert_eq!(stream_bits(&bs, true), compress(&bs).bits);
    }

    #[test]
    fn ratio_monotone_in_occupancy() {
        // fewer occupied frames ⇒ better ratio (invariant used by prop tests)
        let mut last = f64::INFINITY;
        for occupied in [0u64, 100, 400, 704, 1000, 1333] {
            let bs = Bitstream::synthesize(FpgaModel::Xc7s15, occupied, 9);
            let r = compress(&bs).ratio();
            assert!(r <= last + 1e-12, "occupancy {occupied}: {r} > {last}");
            last = r;
        }
    }
}
