//! Deterministic, seeded fault injection and the retry/backoff policy.
//!
//! A [`FaultState`] is the per-device fault stream: it owns a
//! [`Xoshiro256ss`] seeded from the config's [`FaultSpec`] (or a
//! `derive_seed`-split of it for fleet devices) and answers two questions
//! the device layer asks at well-defined points:
//!
//! * [`FaultState::next_config_fault`] — does **this configuration
//!   attempt** fail, and if so with which scenario and after what fraction
//!   of the configuration has already been paid for?
//! * [`FaultState::next_infer_fault`] — is **this inference run**
//!   interrupted by a supply brownout (clearing the loaded image)?
//!
//! Draw discipline (the determinism argument, see `docs/ROBUSTNESS.md`):
//! a question whose total rate is zero consumes **no** RNG output, so a
//! fault-free spec never advances the stream and — since the stream is
//! only ever consulted behind an `Option<FaultState>` that is `None` when
//! [`FaultSpec::enabled`] is false — a fault-free run takes byte-identical
//! code paths to a build without this module. With faults enabled, the
//! sequence of outcomes is a pure function of `(spec, seed, call
//! sequence)`, independent of wall clock, thread count, or allocation
//! order.

use crate::config::schema::FaultSpec;
use crate::util::rng::Xoshiro256ss;
use crate::util::units::Duration;

/// Which configuration fault scenario struck an attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigFaultKind {
    /// Bitstream CRC mismatch, detected at the end of the load: the whole
    /// configuration energy is wasted.
    CrcError,
    /// Corrupted SPI transfer, aborting mid-load.
    SpiCorrupt,
    /// Supply brownout mid-configuration.
    Brownout,
    /// Transient flash read error; fails early in the load, so little
    /// energy is wasted.
    FlashRead,
}

/// One injected configuration fault: the scenario and the fraction of the
/// nominal configuration (time and energy) already spent when it struck.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfigFault {
    /// The scenario that fired.
    pub kind: ConfigFaultKind,
    /// Fraction of the configuration completed before the abort, in
    /// `[0, 1]`. CRC errors pin this to `1.0` (detected at the end);
    /// flash read errors scale it into `[0, 0.1)` (detected early).
    pub fraction: f64,
}

/// Running tally of injected faults, exposed so tests can pin "same seed
/// ⇒ same fault sequence" and reports can break recovery down by cause.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Configuration attempts aborted by a CRC mismatch.
    pub crc_errors: u64,
    /// Configuration attempts aborted by a corrupted SPI transfer.
    pub spi_corruptions: u64,
    /// Configuration attempts aborted by a supply brownout.
    pub config_brownouts: u64,
    /// Configuration attempts aborted by a transient flash read error.
    pub flash_read_errors: u64,
    /// Inference runs interrupted by a supply brownout.
    pub infer_brownouts: u64,
}

impl FaultCounters {
    /// Total configuration-attempt faults across all four scenarios.
    pub fn config_faults(&self) -> u64 {
        self.crc_errors + self.spi_corruptions + self.config_brownouts + self.flash_read_errors
    }
}

/// A seeded per-device fault stream plus the retry policy knobs.
#[derive(Debug, Clone)]
pub struct FaultState {
    spec: FaultSpec,
    rng: Xoshiro256ss,
    counters: FaultCounters,
    draws: u64,
}

impl FaultState {
    /// A stream seeded directly from the spec's own seed (single-device
    /// simulations).
    pub fn new(spec: &FaultSpec) -> FaultState {
        FaultState::with_seed(spec, spec.seed)
    }

    /// A stream with an explicit seed (fleet devices split the spec seed
    /// through the `derive_seed` family so every device gets an
    /// independent, reproducible stream at any thread count).
    pub fn with_seed(spec: &FaultSpec, seed: u64) -> FaultState {
        FaultState {
            spec: spec.clone(),
            rng: Xoshiro256ss::new(seed),
            counters: FaultCounters::default(),
            draws: 0,
        }
    }

    /// The spec this stream was built from.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// The fault tally so far.
    pub fn counters(&self) -> FaultCounters {
        self.counters
    }

    /// How many RNG outputs have been consumed — zero-rate questions must
    /// never advance the stream, and tests pin that here.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// Attempt cap from the spec's retry policy.
    pub fn retry_max(&self) -> u32 {
        self.spec.retry_max
    }

    #[inline]
    fn draw(&mut self) -> f64 {
        self.draws += 1;
        self.rng.next_f64()
    }

    /// Decide whether the next configuration attempt faults. Consumes no
    /// RNG when all four configuration rates are zero; otherwise exactly
    /// one draw on success and two on a fault (scenario + fraction).
    pub fn next_config_fault(&mut self) -> Option<ConfigFault> {
        let spec = &self.spec;
        let total = spec.config_fault_rate();
        if total <= 0.0 {
            return None;
        }
        let u = self.draw();
        // the four scenarios are disjoint slices of [0, total)
        let crc = spec.config_crc_rate;
        let spi = crc + spec.spi_corrupt_rate;
        let brown = spi + spec.brownout_config_rate;
        let kind = if u < crc {
            ConfigFaultKind::CrcError
        } else if u < spi {
            ConfigFaultKind::SpiCorrupt
        } else if u < brown {
            ConfigFaultKind::Brownout
        } else if u < total {
            ConfigFaultKind::FlashRead
        } else {
            return None;
        };
        let frac_draw = self.draw();
        let fraction = match kind {
            // CRC mismatch is only detectable once the full bitstream is in
            ConfigFaultKind::CrcError => {
                self.counters.crc_errors += 1;
                1.0
            }
            ConfigFaultKind::SpiCorrupt => {
                self.counters.spi_corruptions += 1;
                frac_draw
            }
            ConfigFaultKind::Brownout => {
                self.counters.config_brownouts += 1;
                frac_draw
            }
            // flash read faults surface in the first command phase
            ConfigFaultKind::FlashRead => {
                self.counters.flash_read_errors += 1;
                0.1 * frac_draw
            }
        };
        Some(ConfigFault { kind, fraction })
    }

    /// Decide whether the next inference run is interrupted by a supply
    /// brownout; `Some(fraction)` gives how far through the item's compute
    /// phases the supply collapsed. Consumes no RNG at rate zero.
    pub fn next_infer_fault(&mut self) -> Option<f64> {
        if self.spec.brownout_infer_rate <= 0.0 {
            return None;
        }
        let u = self.draw();
        if u < self.spec.brownout_infer_rate {
            self.counters.infer_brownouts += 1;
            Some(self.draw())
        } else {
            None
        }
    }

    /// Backoff charged (powered off, in sim time) after the `failures`-th
    /// consecutive failed attempt: `backoff × 2^(failures−1)`, saturating
    /// at `backoff_cap`.
    pub fn backoff_after(&self, failures: u32) -> Duration {
        let doubling = 2f64.powi(failures.saturating_sub(1).min(62) as i32);
        (self.spec.backoff * doubling).min(self.spec.backoff_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_with(rates: [f64; 5]) -> FaultSpec {
        FaultSpec {
            config_crc_rate: rates[0],
            spi_corrupt_rate: rates[1],
            brownout_config_rate: rates[2],
            flash_read_rate: rates[3],
            brownout_infer_rate: rates[4],
            ..FaultSpec::none()
        }
    }

    #[test]
    fn zero_rates_consume_no_rng() {
        let mut s = FaultState::new(&FaultSpec::none());
        for _ in 0..1000 {
            assert_eq!(s.next_config_fault(), None);
            assert_eq!(s.next_infer_fault(), None);
        }
        assert_eq!(s.draws(), 0);
        assert_eq!(s.counters(), FaultCounters::default());
    }

    #[test]
    fn same_seed_same_fault_sequence() {
        let spec = spec_with([0.05, 0.04, 0.03, 0.02, 0.1]);
        let mut a = FaultState::with_seed(&spec, 42);
        let mut b = FaultState::with_seed(&spec, 42);
        for _ in 0..5000 {
            assert_eq!(a.next_config_fault(), b.next_config_fault());
            assert_eq!(a.next_infer_fault(), b.next_infer_fault());
        }
        assert_eq!(a.counters(), b.counters());
        assert!(a.counters().config_faults() > 0, "rates this high must fire");
        assert!(a.counters().infer_brownouts > 0);
    }

    #[test]
    fn rate_one_always_faults_and_fractions_are_sane() {
        let spec = spec_with([0.25, 0.25, 0.25, 0.25, 1.0]);
        let mut s = FaultState::new(&spec);
        for _ in 0..500 {
            let f = s.next_config_fault().expect("total rate 1.0 must fault");
            assert!((0.0..=1.0).contains(&f.fraction), "{f:?}");
            match f.kind {
                ConfigFaultKind::CrcError => assert_eq!(f.fraction, 1.0),
                ConfigFaultKind::FlashRead => assert!(f.fraction < 0.1),
                _ => {}
            }
            let g = s.next_infer_fault().expect("rate 1.0 must fault");
            assert!((0.0..1.0).contains(&g));
        }
        let c = s.counters();
        assert_eq!(c.config_faults(), 500);
        assert_eq!(c.infer_brownouts, 500);
        // all four scenarios fire at equal rates over 500 attempts
        for n in [c.crc_errors, c.spi_corruptions, c.config_brownouts, c.flash_read_errors] {
            assert!(n > 60, "{c:?}");
        }
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        let spec = FaultSpec {
            backoff: Duration::from_millis(10.0),
            backoff_cap: Duration::from_millis(75.0),
            ..FaultSpec::none()
        };
        let s = FaultState::new(&spec);
        assert_eq!(s.backoff_after(1), Duration::from_millis(10.0));
        assert_eq!(s.backoff_after(2), Duration::from_millis(20.0));
        assert_eq!(s.backoff_after(3), Duration::from_millis(40.0));
        assert_eq!(s.backoff_after(4), Duration::from_millis(75.0));
        assert_eq!(s.backoff_after(200), Duration::from_millis(75.0));
    }

    #[test]
    fn different_seeds_give_different_streams() {
        let spec = spec_with([0.1, 0.1, 0.1, 0.1, 0.0]);
        let mut a = FaultState::with_seed(&spec, 1);
        let mut b = FaultState::with_seed(&spec, 2);
        let mut diverged = false;
        for _ in 0..200 {
            diverged |= a.next_config_fault() != b.next_config_fault();
        }
        assert!(diverged);
    }
}
