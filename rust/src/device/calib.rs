//! Calibration constants for the device substrate.
//!
//! Every constant here is traceable either to the paper's published
//! measurements, to the Xilinx UG470 configuration guide, or to a fit
//! against the paper's published endpoints. DESIGN.md §6 derives each fit;
//! the unit tests below re-derive the paper's headline numbers from them,
//! so a drive-by edit of any constant fails the build.
//!
//! Layout of the idle-power decomposition (Table 3):
//!
//! ```text
//!   134.3 mW baseline idle
//!   ├── 98.8 mW clock reference oscillator   (gated by Method 1)
//!   ├──  1.3 mW FPGA IO standby              (gated by Method 1)
//!   ├── 14.0 mW VCCINT static @ 1.0 V        (scaled by Method 2)
//!   ├──  5.0 mW VCCAUX static @ 1.8 V        (scaled by Method 2)
//!   └── 15.2 mW flash standby                (unavoidable on this board)
//! ```
//!
//! Method 2 undervolts VCCINT 1.0→0.75 V and VCCAUX 1.8→1.5 V; static
//! (leakage-dominated) power scales as (V/V_nom)^LEAKAGE_EXP with
//! LEAKAGE_EXP = 3: leakage falls super-quadratically with voltage, and
//! the cubic fit reproduces Table 3's 24.0 mW exactly.

use crate::config::schema::FpgaModel;
use crate::util::units::{Duration, Power, Voltage};

// ---------------------------------------------------------------------------
// Configuration-phase stages (paper §4.1 / Fig 4)
// ---------------------------------------------------------------------------

/// Setup-stage duration after all rails are up (paper: 27 ms, model-
/// dependent and not optimizable). Includes the memory-clear sub-stage.
pub const SETUP_TIME: Duration = Duration(27.0e-3);

/// Setup-stage power draw (paper §5.2: "consistent ~288 mW").
pub const SETUP_POWER: Power = Power(288.0e-3);

/// Fig 4 sub-stage split of the 27 ms setup (for stage-level reporting):
/// power-on-reset, INIT/clear-configuration-memory, mode-sample remainder.
pub const SETUP_SUBSTAGES: [(&str, Duration); 3] = [
    ("power_on_reset", Duration(2.0e-3)),
    ("clear_config_memory", Duration(23.0e-3)),
    ("mode_sample", Duration(2.0e-3)),
];

/// Startup stage (GTS release, DONE high): sub-ms, folded into loading end
/// in the paper's accounting; kept explicit but zero-cost here.
pub const STARTUP_TIME: Duration = Duration(0.0);

// ---------------------------------------------------------------------------
// SPI bitstream loading (fits to Fig 7 endpoints; DESIGN.md §6)
// ---------------------------------------------------------------------------

/// SPI protocol overhead (read command, address, dummy cycles, resync
/// words) as a fraction of raw transfer time. Fitted: the worst setting
/// (Single/3 MHz/uncompressed) must take 41.4× the optimal 36.145 ms.
pub const SPI_OVERHEAD: f64 = 0.02275;

/// Loading-stage static power floor while the config engine runs, per
/// device (fits: 318.3 mW at (1,3,off) and 445.7 mW at (4,66,on) for the
/// XC7S15; 538.7 mW optimal-setting aggregate for the XC7S25).
pub fn loading_static_power(model: FpgaModel) -> Power {
    match model {
        FpgaModel::Xc7s15 => Power::from_milliwatts(317.03),
        FpgaModel::Xc7s25 => Power::from_milliwatts(410.0),
    }
}

/// Dynamic SPI switching power per (MHz × lane): fitted to the same two
/// XC7S15 endpoints.
pub const SPI_DYN_MW_PER_MHZ_LANE: f64 = 0.42385;

/// Extra switching activity on the SPI data lines when the bitstream is
/// compressed (paper §5.2: "compression led to higher power ... likely due
/// to more switching activities").
pub const COMPRESSED_ACTIVITY: f64 = 1.15;
/// Baseline SPI switching activity (uncompressed bitstreams).
pub const UNCOMPRESSED_ACTIVITY: f64 = 1.0;

// ---------------------------------------------------------------------------
// Synthetic bitstream / frame model (UG470 + fit)
// ---------------------------------------------------------------------------

/// One 7-series configuration frame: 101 words × 32 bits.
pub const FRAME_BITS: u64 = 101 * 32;

/// MFWR (multi-frame write) command overhead per deduplicated frame:
/// 4 words (write-to-FAR + MFWR + data + NOP).
pub const MFWR_CMD_BITS: u64 = 4 * 32;

/// Occupied (non-empty, incompressible) frames for the paper's LSTM
/// hidden-size-20 accelerator, per device. Fitted so the frame-dedup
/// compressor reproduces the loading times implied by Fig 7 / §5.2
/// (XC7S15: 36.145 ms total; XC7S25: 38.09 ms total at optimal settings).
pub fn design_occupied_frames(model: FpgaModel) -> u64 {
    match model {
        FpgaModel::Xc7s15 => 704,
        FpgaModel::Xc7s25 => 794,
    }
}

// ---------------------------------------------------------------------------
// Idle-power decomposition (Table 3; DESIGN.md §6)
// ---------------------------------------------------------------------------

/// Clock-reference oscillator draw (Table 2 footnote: clock reference +
/// flash = 114 mW ⇒ 114 − 15.2 = 98.8 mW).
pub const CLKREF_POWER: Power = Power(98.8e-3);

/// FPGA IO-bank standby draw (gated by Method 1 along with the clock ref;
/// Method 1 saves 100.1 mW total ⇒ 100.1 − 98.8 = 1.3 mW).
pub const IO_STANDBY_POWER: Power = Power(1.3e-3);

/// VCCINT static (leakage) draw at the nominal 1.0 V.
pub const VCCINT_STATIC_NOM: Power = Power(14.0e-3);

/// VCCAUX static draw at the nominal 1.8 V.
pub const VCCAUX_STATIC_NOM: Power = Power(5.0e-3);

/// Flash standby draw — the floor the paper calls out as the limit of its
/// optimization (§5.4).
pub const FLASH_STANDBY_POWER: Power = Power(15.2e-3);

/// Leakage-vs-voltage exponent for undervolted static power.
pub const LEAKAGE_EXP: f64 = 3.0;

/// Nominal and retention (Method 2) rail voltages.
pub const VCCINT_NOM: Voltage = Voltage(1.0);
/// VCCINT retention (Method 2) voltage.
pub const VCCINT_RETENTION: Voltage = Voltage(0.75);
/// VCCAUX nominal voltage.
pub const VCCAUX_NOM: Voltage = Voltage(1.8);
/// VCCAUX retention (Method 2) voltage.
pub const VCCAUX_RETENTION: Voltage = Voltage(1.5);

// ---------------------------------------------------------------------------
// On-Off power-cycle transient (DESIGN.md §6)
// ---------------------------------------------------------------------------

/// Energy charged once per power-on (rail ramp + decoupling-capacitor
/// inrush). The paper's published n_max = 346,073 under 4147 J implies
/// 0.1244 mJ per item above the Table 2 phase sum; the same constant
/// independently reproduces both published crossovers (89.21 / 499.06 ms).
pub const POWER_ON_TRANSIENT_MJ: f64 = 0.1244;

// ---------------------------------------------------------------------------
// MCU (RP2040) and battery
// ---------------------------------------------------------------------------

/// RP2040 sleep current (paper §2: 180 µA) at the 3.3 V MCU rail.
pub const MCU_SLEEP_CURRENT_UA: f64 = 180.0;
/// MCU rail voltage.
pub const MCU_RAIL: Voltage = Voltage(3.3);

/// RP2040 active draw while coordinating a request (datasheet-typical
/// ~20 mA at 3.3 V; brief, not part of the paper's FPGA-side budget).
pub const MCU_ACTIVE_POWER: Power = Power(66.0e-3);

/// Battery budget (paper §2: 320 mAh LiPo ≈ 4147 J).
pub const BATTERY_BUDGET_J: f64 = 4147.0;
/// Battery capacity in mAh (paper §2: 320 mAh LiPo).
pub const BATTERY_CAPACITY_MAH: f64 = 320.0;

/// PAC1934 sampling rate (paper §2: 1024 samples/s per rail).
pub const PAC1934_HZ: f64 = 1024.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Energy;

    #[test]
    fn idle_decomposition_sums_to_baseline() {
        let total = CLKREF_POWER
            + IO_STANDBY_POWER
            + VCCINT_STATIC_NOM
            + VCCAUX_STATIC_NOM
            + FLASH_STANDBY_POWER;
        assert!((total.milliwatts() - 134.3).abs() < 1e-9, "{}", total.milliwatts());
    }

    #[test]
    fn method1_reproduces_table3() {
        // Gate clkref + IO: 134.3 − (98.8 + 1.3) = 34.2 mW
        let m1 = VCCINT_STATIC_NOM + VCCAUX_STATIC_NOM + FLASH_STANDBY_POWER;
        assert!((m1.milliwatts() - 34.2).abs() < 1e-9);
        // Paper says 74.38%; its rounded Table 3 powers give 74.53% —
        // the authors evidently divided unrounded measurements. We assert
        // against the rounded-consistent value with a note in EXPERIMENTS.md.
        let saved = 1.0 - m1.milliwatts() / 134.3;
        assert!((saved - 0.7453).abs() < 2e-3, "saved={saved}");
    }

    #[test]
    fn method12_reproduces_table3() {
        let scale_int = (VCCINT_RETENTION.volts() / VCCINT_NOM.volts()).powf(LEAKAGE_EXP);
        let scale_aux = (VCCAUX_RETENTION.volts() / VCCAUX_NOM.volts()).powf(LEAKAGE_EXP);
        let m12 = VCCINT_STATIC_NOM * scale_int
            + VCCAUX_STATIC_NOM * scale_aux
            + FLASH_STANDBY_POWER;
        assert!((m12.milliwatts() - 24.0).abs() < 0.05, "{}", m12.milliwatts());
        // Paper says 81.98%; rounded Table 3 powers give 82.13% (same
        // rounding effect as Method 1).
        let saved = 1.0 - m12.milliwatts() / 134.3;
        assert!((saved - 0.8213).abs() < 2e-3, "saved={saved}");
    }

    #[test]
    fn clkref_plus_flash_is_table2_footnote() {
        // Table 2: inference power "includes the 114 mW for clock reference
        // and flash chip"
        let p = CLKREF_POWER + FLASH_STANDBY_POWER;
        assert!((p.milliwatts() - 114.0).abs() < 1e-9);
    }

    #[test]
    fn setup_substages_sum_to_setup_time() {
        let total: Duration = SETUP_SUBSTAGES
            .iter()
            .fold(Duration::ZERO, |acc, (_, d)| acc + *d);
        assert!((total.secs() - SETUP_TIME.secs()).abs() < 1e-12);
    }

    #[test]
    fn setup_energy_near_papers_7mj_floor() {
        // "the configuration phase can only be reduced from 11.85 mJ to 7 mJ"
        let e: Energy = SETUP_POWER * SETUP_TIME;
        assert!((e.millijoules() - 7.776).abs() < 1e-9);
    }

    #[test]
    fn loading_power_fits_published_endpoints() {
        // worst: single SPI, 3 MHz, uncompressed → ≈318.3 mW
        let worst = loading_static_power(FpgaModel::Xc7s15).milliwatts()
            + SPI_DYN_MW_PER_MHZ_LANE * 1.0 * 3.0 * UNCOMPRESSED_ACTIVITY;
        assert!((worst - 318.3).abs() < 0.1, "worst={worst}");
        // optimal: quad SPI, 66 MHz, compressed → ≈445.7 mW
        let opt = loading_static_power(FpgaModel::Xc7s15).milliwatts()
            + SPI_DYN_MW_PER_MHZ_LANE * 4.0 * 66.0 * COMPRESSED_ACTIVITY;
        assert!((opt - 445.7).abs() < 0.2, "opt={opt}");
    }

    #[test]
    fn battery_budget_matches_paper() {
        assert_eq!(BATTERY_BUDGET_J, 4147.0);
    }

    #[test]
    fn mcu_sleep_power_sub_milliwatt() {
        let p = MCU_RAIL * crate::util::units::Current::from_microamps(MCU_SLEEP_CURRENT_UA);
        assert!((p.milliwatts() - 0.594).abs() < 1e-9);
    }
}
