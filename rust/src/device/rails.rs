//! The platform's power-rail tree (paper Fig 3: seven monitored rails).
//!
//! [`RailSet`] composes the FPGA supply regulators (VCCINT/VCCAUX/VCCO),
//! the clock-reference and flash rails, and the MCU rail, and computes the
//! aggregate idle power for each power-saving configuration — reproducing
//! Table 3 from the per-rail decomposition rather than hardcoding totals.

use crate::device::calib::{
    CLKREF_POWER, FLASH_STANDBY_POWER, IO_STANDBY_POWER, MCU_RAIL, MCU_SLEEP_CURRENT_UA,
    VCCAUX_NOM, VCCAUX_RETENTION, VCCAUX_STATIC_NOM, VCCINT_NOM, VCCINT_RETENTION,
    VCCINT_STATIC_NOM,
};
use crate::device::regulator::{RegMode, Regulator};
use crate::util::units::{Current, Power};

/// Identifiers for the seven monitored rails (Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rail {
    /// MCU supply.
    McuVdd,
    /// FPGA IO-bank supply.
    Fpga3v3Vcco,
    /// FPGA core supply.
    FpgaVccint,
    /// FPGA auxiliary supply.
    FpgaVccaux,
    /// Configuration-flash supply.
    Flash3v3,
    /// Clock-reference oscillator supply.
    ClkRef3v3,
    /// Power-monitor supply.
    Monitor3v3,
}

impl Rail {
    /// All seven rails, in Fig 3 order.
    pub const ALL: [Rail; 7] = [
        Rail::McuVdd,
        Rail::Fpga3v3Vcco,
        Rail::FpgaVccint,
        Rail::FpgaVccaux,
        Rail::Flash3v3,
        Rail::ClkRef3v3,
        Rail::Monitor3v3,
    ];

    /// Schematic net name.
    pub fn name(&self) -> &'static str {
        match self {
            Rail::McuVdd => "MCU_VDD",
            Rail::Fpga3v3Vcco => "FPGA_VCCO",
            Rail::FpgaVccint => "FPGA_VCCINT",
            Rail::FpgaVccaux => "FPGA_VCCAUX",
            Rail::Flash3v3 => "FLASH_3V3",
            Rail::ClkRef3v3 => "CLKREF_3V3",
            Rail::Monitor3v3 => "MONITOR_3V3",
        }
    }
}

/// Idle-phase power-saving configuration (paper §4.2 / §5.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PowerSaving {
    /// Method 1: deactivate IOs and the clock reference while idle.
    pub method1: bool,
    /// Method 2: drop VCCINT/VCCAUX to retention voltages while idle.
    pub method2: bool,
}

impl PowerSaving {
    /// No power saving: everything stays up while idle.
    pub const BASELINE: PowerSaving = PowerSaving {
        method1: false,
        method2: false,
    };
    /// Method 1: gate IOs + clock reference while idle.
    pub const M1: PowerSaving = PowerSaving {
        method1: true,
        method2: false,
    };
    /// Methods 1+2: also undervolt VCCINT/VCCAUX to retention.
    pub const M12: PowerSaving = PowerSaving {
        method1: true,
        method2: true,
    };

    /// Human-readable level name.
    pub fn label(&self) -> &'static str {
        match (self.method1, self.method2) {
            (false, false) => "baseline",
            (true, false) => "method1",
            (true, true) => "method1+2",
            (false, true) => "method2-only",
        }
    }
}

/// The FPGA-side rail tree.
#[derive(Debug, Clone)]
pub struct RailSet {
    /// FPGA core regulator.
    pub vccint: Regulator,
    /// FPGA auxiliary regulator.
    pub vccaux: Regulator,
    /// Clock-reference oscillator currently powered?
    pub clkref_on: bool,
    /// FPGA IO banks active?
    pub io_on: bool,
    /// Flash chip present (standby floor whenever the board is powered).
    pub flash_on: bool,
}

impl Default for RailSet {
    fn default() -> Self {
        Self::new()
    }
}

impl RailSet {
    /// All rails off (board cold).
    pub fn new() -> RailSet {
        RailSet {
            vccint: Regulator::new("VCCINT", VCCINT_NOM, VCCINT_RETENTION, VCCINT_STATIC_NOM),
            vccaux: Regulator::new("VCCAUX", VCCAUX_NOM, VCCAUX_RETENTION, VCCAUX_STATIC_NOM),
            clkref_on: false,
            io_on: false,
            flash_on: false,
        }
    }

    /// Power everything up to the operational state.
    pub fn power_up(&mut self) {
        self.vccint.mode = RegMode::Nominal;
        self.vccaux.mode = RegMode::Nominal;
        self.clkref_on = true;
        self.io_on = true;
        self.flash_on = true;
    }

    /// Cut all FPGA rails (configuration is lost — SRAM device).
    pub fn power_down(&mut self) {
        self.vccint.mode = RegMode::Off;
        self.vccaux.mode = RegMode::Off;
        self.clkref_on = false;
        self.io_on = false;
        // flash stays powered: it shares the always-on 3V3 (paper §5.4
        // counts its 15.2 mW floor; the paper's *accounting* zeroes the
        // off state — Board::off_for handles that distinction)
        self.flash_on = true;
    }

    /// Enter the idle state under a power-saving configuration.
    pub fn enter_idle(&mut self, saving: PowerSaving) {
        if saving.method1 {
            self.clkref_on = false;
            self.io_on = false;
        } else {
            self.clkref_on = true;
            self.io_on = true;
        }
        let mode = if saving.method2 {
            RegMode::Retention
        } else {
            RegMode::Nominal
        };
        self.vccint.mode = mode;
        self.vccaux.mode = mode;
        self.flash_on = true;
    }

    /// Restore operational state from idle (exit power-saving). The paper
    /// verified on hardware that configuration is retained across this.
    pub fn exit_idle(&mut self) {
        self.power_up();
    }

    /// True if the FPGA's configuration SRAM still holds its bitstream.
    pub fn configuration_retained(&self) -> bool {
        self.vccint.retains_state() && self.vccaux.retains_state()
    }

    /// True if the fabric can actually run (data transfer + inference).
    pub fn operational(&self) -> bool {
        self.vccint.operational() && self.vccaux.operational() && self.io_on
    }

    /// Aggregate idle/static power of the FPGA-side rails in their current
    /// state (excludes active-phase dynamic power, which comes from the
    /// workload-item profile).
    pub fn static_power(&self) -> Power {
        let mut p = Power::ZERO;
        if self.flash_on {
            p += FLASH_STANDBY_POWER;
        }
        if self.clkref_on {
            p += CLKREF_POWER;
        }
        if self.io_on {
            p += IO_STANDBY_POWER;
        }
        p += self.vccint.static_power();
        p += self.vccaux.static_power();
        p
    }

    /// Idle power for a saving configuration (pure query; Table 3).
    pub fn idle_power(saving: PowerSaving) -> Power {
        let mut rails = RailSet::new();
        rails.enter_idle(saving);
        rails.static_power()
    }

    /// MCU sleep power (separate budget domain; paper measures the FPGA
    /// side, the MCU is "usually in sleep mode, consuming 180 µA").
    pub fn mcu_sleep_power() -> Power {
        MCU_RAIL * Current::from_microamps(MCU_SLEEP_CURRENT_UA)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_baseline() {
        let p = RailSet::idle_power(PowerSaving::BASELINE);
        assert!((p.milliwatts() - 134.3).abs() < 1e-9, "{}", p.milliwatts());
    }

    #[test]
    fn table3_method1() {
        let p = RailSet::idle_power(PowerSaving::M1);
        assert!((p.milliwatts() - 34.2).abs() < 1e-9, "{}", p.milliwatts());
    }

    #[test]
    fn table3_method12() {
        let p = RailSet::idle_power(PowerSaving::M12);
        assert!((p.milliwatts() - 24.0).abs() < 0.05, "{}", p.milliwatts());
    }

    #[test]
    fn power_down_loses_configuration() {
        let mut rails = RailSet::new();
        rails.power_up();
        assert!(rails.configuration_retained());
        rails.power_down();
        assert!(!rails.configuration_retained());
        // flash still draws its floor while the board lives
        assert_eq!(rails.static_power(), FLASH_STANDBY_POWER);
    }

    #[test]
    fn idle_retains_configuration_in_all_modes() {
        for saving in [PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12] {
            let mut rails = RailSet::new();
            rails.power_up();
            rails.enter_idle(saving);
            assert!(rails.configuration_retained(), "{saving:?}");
            rails.exit_idle();
            assert!(rails.operational());
            assert!(rails.configuration_retained());
        }
    }

    #[test]
    fn retention_mode_is_not_operational() {
        let mut rails = RailSet::new();
        rails.power_up();
        rails.enter_idle(PowerSaving::M12);
        assert!(!rails.operational());
    }

    #[test]
    fn operational_power_exceeds_every_idle_mode() {
        let mut rails = RailSet::new();
        rails.power_up();
        let active_static = rails.static_power();
        for saving in [PowerSaving::BASELINE, PowerSaving::M1, PowerSaving::M12] {
            assert!(active_static >= RailSet::idle_power(saving));
        }
    }

    #[test]
    fn mcu_sleep_power_matches_paper() {
        let p = RailSet::mcu_sleep_power();
        assert!((p.milliwatts() - 0.594).abs() < 1e-9);
    }

    #[test]
    fn rail_names_unique() {
        let names: std::collections::BTreeSet<_> =
            Rail::ALL.iter().map(|r| r.name()).collect();
        assert_eq!(names.len(), 7);
    }
}
