//! SPI configuration-port link model.
//!
//! Transfer timing for bitstream loading through the FPGA's master-SPI
//! configuration interface: `T = bits · (1 + η) / (width · f)` where η is
//! the protocol overhead (read command, address, dummy cycles, resync) and
//! `width · f` is the aggregate line rate. Loading power is a static floor
//! (configuration engine + flash read) plus a dynamic term proportional to
//! the switching rate, higher for compressed streams (denser transitions).
//! Constants are fitted to the paper's published endpoints (DESIGN.md §6).

use crate::config::schema::{FpgaModel, SpiConfig};
use crate::device::calib::{
    loading_static_power, COMPRESSED_ACTIVITY, SPI_DYN_MW_PER_MHZ_LANE, SPI_OVERHEAD,
    UNCOMPRESSED_ACTIVITY,
};
use crate::util::units::{Duration, Power};

/// Raw line rate in bits/second for a setting.
pub fn line_rate_bps(spi: &SpiConfig) -> f64 {
    spi.buswidth as f64 * spi.freq_mhz * 1e6
}

/// Time to shift `bits` through the port, including protocol overhead.
pub fn transfer_time(spi: &SpiConfig, bits: u64) -> Duration {
    Duration::from_secs(bits as f64 * (1.0 + SPI_OVERHEAD) / line_rate_bps(spi))
}

/// Average power during the loading stage for a setting.
pub fn loading_power(model: FpgaModel, spi: &SpiConfig) -> Power {
    let activity = if spi.compressed {
        COMPRESSED_ACTIVITY
    } else {
        UNCOMPRESSED_ACTIVITY
    };
    loading_static_power(model)
        + Power::from_milliwatts(
            SPI_DYN_MW_PER_MHZ_LANE * spi.buswidth as f64 * spi.freq_mhz * activity,
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_rates() {
        assert_eq!(line_rate_bps(&SpiConfig::worst()), 3e6);
        assert_eq!(line_rate_bps(&SpiConfig::optimal()), 264e6);
    }

    #[test]
    fn worst_case_transfer_time_matches_fig7() {
        // Single SPI @ 3 MHz, uncompressed XC7S15 stream → ≈1469.6 ms
        let t = transfer_time(&SpiConfig::worst(), FpgaModel::Xc7s15.bitstream_bits());
        assert!((t.millis() - 1469.6).abs() < 1.0, "t={}", t.millis());
    }

    #[test]
    fn transfer_time_scales_inversely_with_rate() {
        let bits = 1_000_000;
        let slow = transfer_time(&SpiConfig::worst(), bits);
        let fast = transfer_time(&SpiConfig::optimal(), bits);
        assert!((slow / fast - 88.0).abs() < 1e-9); // 264/3
    }

    #[test]
    fn loading_power_endpoints() {
        let worst = loading_power(FpgaModel::Xc7s15, &SpiConfig::worst());
        assert!((worst.milliwatts() - 318.3).abs() < 0.1);
        let opt = loading_power(FpgaModel::Xc7s15, &SpiConfig::optimal());
        assert!((opt.milliwatts() - 445.7).abs() < 0.2);
    }

    #[test]
    fn compression_increases_loading_power() {
        let mut spi = SpiConfig::optimal();
        let with = loading_power(FpgaModel::Xc7s15, &spi);
        spi.compressed = false;
        let without = loading_power(FpgaModel::Xc7s15, &spi);
        assert!(with > without);
    }

    #[test]
    fn power_monotone_in_width_and_freq() {
        let mut last = Power::ZERO;
        for &w in &SpiConfig::BUSWIDTHS {
            let p = loading_power(
                FpgaModel::Xc7s15,
                &SpiConfig {
                    buswidth: w,
                    freq_mhz: 33.0,
                    compressed: false,
                },
            );
            assert!(p > last);
            last = p;
        }
        last = Power::ZERO;
        for &f in &SpiConfig::FREQS_MHZ {
            let p = loading_power(
                FpgaModel::Xc7s15,
                &SpiConfig {
                    buswidth: 2,
                    freq_mhz: f,
                    compressed: false,
                },
            );
            assert!(p > last);
            last = p;
        }
    }

    #[test]
    fn xc7s25_draws_more_during_loading() {
        let p15 = loading_power(FpgaModel::Xc7s15, &SpiConfig::optimal());
        let p25 = loading_power(FpgaModel::Xc7s25, &SpiConfig::optimal());
        assert!(p25 > p15);
    }
}
