//! # idlewait — "Idle is the New Sleep" reproduction
//!
//! A production-quality reproduction of Qian et al., *Idle is the New
//! Sleep: Configuration-Aware Alternative to Powering Off FPGA-Based DL
//! Accelerators During Inactivity* (CS.AR 2024), built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the duty-cycle coordinator, the full device
//!   substrate (Spartan-7 configuration FSM, SPI/flash, power rails,
//!   battery, PAC1934 monitors, RP2040 MCU), a discrete-event simulator,
//!   the paper's analytical model (Eqs 1–4), the On-Off / Idle-Waiting
//!   strategies with idle-power-saving methods, and the experiment
//!   harness regenerating every table and figure.
//! * **L2/L1 (python, build-time only)** — the LSTM accelerator payload
//!   (JAX model + Pallas kernels) AOT-lowered to HLO text, executed from
//!   Rust via the PJRT C API (`runtime` module). Python is never on the
//!   request path.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod runner;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod tuner;
pub mod util;
pub mod device;
pub mod energy;
pub mod experiments;
pub mod strategies;
pub mod coordinator;
