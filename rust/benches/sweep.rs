//! Bench: the unified sweep engine's throughput on the Experiment 2
//! full-fidelity grid (10–120 ms at 0.01 ms = 11,001 cells), at 1 and 4
//! threads and at the machine's full parallelism, reported as cells/sec
//! — plus the exp4 policy × tunable × arrival grid (90 DES lifetimes per sweep),
//! which keeps the new policy subsystem on the cells/sec trajectory.
//!
//! This is the bench that backs the runner's headline claim: the
//! multi-threaded sweep is byte-identical to the serial one (asserted
//! here before timing) and measurably faster. Since the hot-path kernel
//! PR the runner steals work in batches (uneven cells no longer
//! serialize on the slowest chunk) and cells reuse per-worker DES
//! state, so the exp4 grid — whose trace columns and embedded tuner are
//! far heavier than its periodic cells — is the interesting row here.
//! (`repro bench --json` runs the same targets machine-readably.)
//!
//! Run: `cargo bench --bench sweep` (IDLEWAIT_BENCH_QUICK=1 for CI).

use idlewait::bench::{black_box, quick_mode, Bench};
use idlewait::config::paper_default;
use idlewait::experiments::exp2;
use idlewait::experiments::exp4_policies::{self, Exp4Config};
use idlewait::runner::SweepRunner;
use idlewait::util::table::{fnum, Table};

fn main() {
    let cfg = paper_default();
    let step = if quick_mode() { 0.1 } else { 0.01 };

    // determinism gate: don't bother timing a runner that's wrong
    let serial = exp2::run_threaded(&cfg, step, &SweepRunner::single());
    let cells = serial.samples.len();
    let reference = serial.to_csv().render();
    let max = SweepRunner::max_threads();
    let mut counts = vec![1usize];
    if max > 1 {
        counts.push(4.min(max));
    }
    if max > 4 {
        counts.push(max);
    }
    for &threads in &counts {
        let out = exp2::run_threaded(&cfg, step, &SweepRunner::new(threads))
            .to_csv()
            .render();
        assert_eq!(out, reference, "threads={threads} diverged from serial");
    }
    println!(
        "determinism check passed: {} cells byte-identical at threads {:?}\n",
        cells, counts
    );

    let mut bench = Bench::new(format!(
        "exp2 full-fidelity sweep ({cells} cells, step {step} ms)"
    ));
    let mut rows: Vec<(usize, f64)> = Vec::new();
    for &threads in &counts {
        let runner = SweepRunner::new(threads);
        let r = bench.bench(format!("threads={threads}"), || {
            black_box(exp2::run_threaded(&cfg, step, &runner).samples.len());
        });
        // ns per full sweep → cells per second
        rows.push((threads, cells as f64 * 1e9 / r.ns_per_iter()));
    }
    bench.finish();

    let mut t = Table::new(&["threads", "cells/sec", "speedup vs 1 thread"])
        .with_title("sweep throughput");
    let base = rows[0].1;
    for (threads, cps) in &rows {
        t.row(&[
            threads.to_string(),
            fnum(*cps, 0),
            fnum(cps / base, 2),
        ]);
    }
    print!("{}", t.render());

    // --- exp4 policy grid: 15 policy variants (incl. the tuned row) × 6
    // arrivals, each cell a full DES lifetime run — the heavy-cell regime
    // of the sweep engine ---
    let e4 = Exp4Config {
        items: if quick_mode() { 200 } else { 2_000 },
        period_ms: 40.0,
        seed: 7,
    };
    let e4_reference = exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::single())
        .expect("exp4 serial run")
        .to_csv()
        .render();
    let e4_parallel =
        exp4_policies::run_threaded(&cfg, &e4, &SweepRunner::auto()).expect("exp4 parallel run");
    let e4_cells = e4_parallel.rows.len();
    assert_eq!(
        e4_parallel.to_csv().render(),
        e4_reference,
        "exp4 diverged from serial"
    );
    let mut bench = Bench::new(format!(
        "exp4 policy grid ({e4_cells} cells x {} items)",
        e4.items
    ));
    let mut rows: Vec<(usize, f64)> = Vec::new();
    for &threads in &counts {
        let runner = SweepRunner::new(threads);
        let r = bench.bench(format!("threads={threads}"), || {
            black_box(exp4_policies::run_threaded(&cfg, &e4, &runner).unwrap().rows.len());
        });
        rows.push((threads, e4_cells as f64 * 1e9 / r.ns_per_iter()));
    }
    bench.finish();
    let mut t = Table::new(&["threads", "cells/sec", "speedup vs 1 thread"])
        .with_title("exp4 policy-sweep throughput");
    let base = rows[0].1;
    for (threads, cps) in &rows {
        t.row(&[threads.to_string(), fnum(*cps, 0), fnum(cps / base, 2)]);
    }
    print!("{}", t.render());
}
