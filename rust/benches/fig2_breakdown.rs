//! Bench: regenerate Fig 2 (energy breakdown of a workload item) and
//! time the phase-breakdown computation.
//!
//! Run: `cargo bench --bench fig2_breakdown`

use idlewait::bench::{black_box, Bench};
use idlewait::config::paper_default;
use idlewait::energy::phase::Breakdown;
use idlewait::experiments::fig2;

fn main() {
    // --- regenerate the figure ---
    let profile = fig2::run();
    print!("{}", profile.render());

    // --- timing ---
    let item = paper_default().item;
    let mut bench = Bench::new("fig2: workload-item energy breakdown");
    bench.bench("fig2::run (device-model reconstruction)", || {
        black_box(fig2::run().config_fraction());
    });
    bench.bench("Breakdown::of_item (Table 2 item)", || {
        black_box(Breakdown::of_item(&item).total);
    });
    bench.finish();
}
