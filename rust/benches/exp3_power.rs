//! Bench: regenerate Experiment 3 / Table 3 + Figs 10–11 (idle power
//! saving) and time the rail-model queries.
//!
//! Run: `cargo bench --bench exp3_power`

use idlewait::bench::{black_box, quick_mode, Bench};
use idlewait::config::paper_default;
use idlewait::device::rails::{PowerSaving, RailSet};
use idlewait::experiments::exp3;

fn main() {
    let cfg = paper_default();

    // --- regenerate ---
    let step = if quick_mode() { 1.0 } else { 0.01 };
    let result = exp3::run(&cfg, step);
    print!("{}", result.render_table3());
    print!("{}", result.render_figs());
    print!("{}", result.render_summary());

    // --- timing ---
    let mut bench = Bench::new("exp3: rail model + sweep");
    bench.bench("RailSet::idle_power(M12) (Table 3 query)", || {
        black_box(RailSet::idle_power(PowerSaving::M12).milliwatts());
    });
    bench.bench("enter/exit idle transition pair", || {
        let mut rails = RailSet::new();
        rails.power_up();
        rails.enter_idle(PowerSaving::M12);
        rails.exit_idle();
        black_box(rails.static_power().milliwatts());
    });
    bench.bench("full Fig 10/11 sweep (11,001 pts × 3 modes)", || {
        black_box(exp3::run(&cfg, 0.01).m12_items_x());
    });
    bench.finish();
}
