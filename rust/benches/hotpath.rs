//! Bench: the whole-stack hot paths (§Perf deliverable).
//!
//! * L3 DES: simulated workload items per second (the validation run's
//!   cost driver) + event-queue throughput.
//! * L3 serving: end-to-end request cost including real PJRT inference.
//! * PJRT: raw LSTM forecast latency (f32 and int8 variants) — the L1/L2
//!   artifact executing under the CPU stand-in.
//!
//! Run: `cargo bench --bench hotpath`

use idlewait::bench::{black_box, targets, Bench};
use idlewait::config::paper_default;
use idlewait::coordinator::requests::Periodic;
use idlewait::coordinator::server::{serve, SensorSource, ServerConfig};
use idlewait::energy::analytical::Analytical;
use idlewait::runtime::inference::Variant;
use idlewait::strategies::strategy::IdleWaiting;
use idlewait::util::units::Duration;

fn main() {
    let cfg = paper_default();
    let mut bench = Bench::new("whole-stack hot paths");

    // --- L3 DES ---
    // Shared bodies with `repro bench --json` (bench::targets), so the
    // two harnesses measure the identical workload. The unsuffixed DES
    // targets run the batched structure-of-arrays kernel (the production
    // sweep/tuner shape); the `scalar` pair runs the per-gap event-driven
    // fast path and the `golden` target the pre-kernel Board FSM, for an
    // in-run three-tier speedup readout.
    targets::des_idle_waiting(&mut bench, "DES: 10k idle-waiting items (batched)", &cfg, 10_000);
    targets::des_onoff(&mut bench, "DES: 10k on-off items (batched)", &cfg, 10_000);
    targets::des_idle_waiting_scalar(
        &mut bench,
        "DES scalar fast path: 10k idle-waiting items",
        &cfg,
        10_000,
    );
    targets::des_onoff_scalar(&mut bench, "DES scalar fast path: 10k on-off items", &cfg, 10_000);
    // the pre-kernel reference path, for an in-run speedup readout
    targets::des_onoff_golden(&mut bench, "DES golden reference: 10k on-off items", &cfg, 10_000);

    // --- sim core ---
    targets::event_queue(&mut bench, "event queue: 1k schedule+pop");

    // --- fleet DES (quick shapes under IDLEWAIT_BENCH_QUICK, else full) ---
    let quick = idlewait::bench::quick_mode();
    targets::fleet_step_devices(&mut bench, "fleet survey: device-gap steps", &cfg, quick);
    targets::fleet_route_requests(&mut bench, "fleet routing: least-loaded requests", &cfg, quick);

    // --- analytical (used inside every sweep point) ---
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    bench.bench("analytical n_max (idle-waiting)", || {
        black_box(model.n_max_idle_waiting(
            Duration::from_millis(40.0),
            model.item.idle_power_baseline,
        ));
    });

    // --- PJRT inference (requires artifacts) ---
    match idlewait::runtime::pool::default_runtime() {
        Ok(runtime) => {
            let window = runtime.manifest.selfcheck.window.clone();
            bench.bench("PJRT LSTM forecast (f32, 24x6 window)", || {
                black_box(
                    runtime
                        .forecast(&window, Variant::Forecast)
                        .unwrap()
                        .forecast,
                );
            });
            bench.bench("PJRT LSTM forecast (int8 activations)", || {
                black_box(
                    runtime
                        .forecast(&window, Variant::ForecastInt8)
                        .unwrap()
                        .forecast,
                );
            });
            if let Some(batch) = runtime.batch_size() {
                let (rows, cols) = runtime.window_shape();
                let mut buffer = Vec::with_capacity(batch * rows * cols);
                for b in 0..batch {
                    buffer.extend(window.iter().map(|v| v + 0.01 * b as f32));
                }
                bench.bench(
                    format!("PJRT LSTM forecast (batch of {batch}, 1 dispatch)"),
                    || {
                        black_box(runtime.forecast_batch(&buffer).unwrap().len());
                    },
                );
            }
            let mut sensor = SensorSource::new(
                runtime.manifest.window,
                runtime.manifest.input_size,
                1,
            );
            bench.bench("sensor window synthesis", || {
                black_box(sensor.next_window().len());
            });
            // end-to-end serving cost per request (energy sim + real infer)
            bench.bench("serve: 50-request duty cycle (idle-waiting)", || {
                let server_cfg = ServerConfig {
                    sim: &cfg,
                    variant: Variant::Forecast,
                    max_requests: 50,
                };
                let mut arrivals = Periodic {
                    period: Duration::from_millis(40.0),
                };
                black_box(
                    serve(&server_cfg, &runtime, &mut IdleWaiting::baseline(), &mut arrivals)
                        .unwrap()
                        .metrics
                        .requests,
                );
            });
        }
        Err(err) => {
            eprintln!("skipping PJRT benches: {err:#} (run `make artifacts`)");
        }
    }

    bench.finish();

    // derived headline: DES items/sec for the §Perf log
    println!("\nnote: 'DES: 10k items' p50 ÷ 10,000 = per-item cost;");
    println!("      the full §5.3 validation simulates ~1.12M items.");
}
