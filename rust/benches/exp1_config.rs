//! Bench: regenerate Experiment 1 / Fig 7 (the 66-point configuration
//! sweep on both devices) and time the underlying device-model paths.
//!
//! Run: `cargo bench --bench exp1_config`

use idlewait::bench::{black_box, Bench};
use idlewait::config::schema::{FpgaModel, SpiConfig};
use idlewait::device::bitstream::Bitstream;
use idlewait::device::compression::compress;
use idlewait::device::config_fsm::ConfigProfile;
use idlewait::device::flash::StoredImage;
use idlewait::experiments::exp1;

fn main() {
    // --- regenerate the table/figure ---
    for model in [FpgaModel::Xc7s15, FpgaModel::Xc7s25] {
        let result = exp1::run(model);
        print!("{}", result.render_fig7());
        print!("{}", result.render_summary());
        println!();
    }

    // --- timing ---
    let mut bench = Bench::new("exp1: configuration sweep machinery");
    bench.bench("full 66-point sweep (XC7S15)", || {
        black_box(exp1::run(FpgaModel::Xc7s15).energy_improvement());
    });
    let bitstream = Bitstream::lstm_accelerator(FpgaModel::Xc7s15);
    bench.bench("bitstream synthesis (1333 frames)", || {
        black_box(Bitstream::lstm_accelerator(FpgaModel::Xc7s15).n_frames());
    });
    bench.bench("frame-dedup compression", || {
        black_box(compress(&bitstream).bits);
    });
    let image = StoredImage::new(bitstream.clone(), true);
    bench.bench("single ConfigProfile::compute", || {
        black_box(
            ConfigProfile::compute(FpgaModel::Xc7s15, SpiConfig::optimal(), &image)
                .total_energy(),
        );
    });
    bench.finish();
}
