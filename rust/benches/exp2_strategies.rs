//! Bench: regenerate Experiment 2 / Figs 8–9 (Idle-Waiting vs On-Off
//! sweep at the paper's 0.01 ms resolution) and time the analytical path.
//!
//! Run: `cargo bench --bench exp2_strategies`

use idlewait::bench::{black_box, quick_mode, Bench};
use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::experiments::exp2;
use idlewait::util::units::Duration;

fn main() {
    let cfg = paper_default();

    // --- regenerate the figures at paper resolution ---
    let step = if quick_mode() { 1.0 } else { 0.01 };
    let result = exp2::run(&cfg, step);
    print!("{}", result.render_figs());
    print!("{}", result.render_summary(&cfg));

    // --- timing ---
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let mut bench = Bench::new("exp2: analytical model hot path");
    bench.bench("single n_max prediction (Idle-Waiting)", || {
        black_box(
            model
                .predict(PolicySpec::IdleWaiting, Duration::from_millis(40.0))
                .n_max,
        );
    });
    bench.bench("single n_max prediction (On-Off)", || {
        black_box(
            model
                .predict(PolicySpec::OnOff, Duration::from_millis(40.0))
                .n_max,
        );
    });
    bench.bench("crossover (closed form)", || {
        black_box(crossover::asymptotic(&model, model.item.idle_power_baseline).millis());
    });
    bench.bench("crossover (bisection, 0.01 ms tol)", || {
        black_box(crossover::exact(
            &model,
            model.item.idle_power_baseline,
            Duration::from_millis(37.0),
            Duration::from_millis(600.0),
            Duration::from_millis(0.01),
        ));
    });
    bench.bench("full Fig 8/9 sweep (11,001 pts × 2 strategies)", || {
        black_box(exp2::run(&cfg, 0.01).samples.len());
    });
    bench.finish();
}
