//! Lifetime planner: the paper's analytical model as a deployment tool.
//!
//! Given an application's request period and battery, prints the
//! items/lifetime for every strategy, the break-even crossovers, a
//! gap-policy analysis for *irregular* arrivals (Poisson — the paper's
//! stated future work) showing where the online ski-rental policies and
//! the clairvoyant oracle beat both fixed strategies, and a tunable
//! sweep: the windowed-quantile predictor's `quantile` knob against a
//! bursty IoT trace, the concrete "which PolicyParams should I deploy?"
//! question.
//!
//! ```sh
//! cargo run --release --example lifetime_planner [-- <period_ms>]
//! ```

use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::coordinator::requests::{Poisson, TraceReplay};
use idlewait::coordinator::tracegen::{self, TraceKind};
use idlewait::device::rails::PowerSaving;
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::strategies::simulate::simulate;
use idlewait::strategies::strategy::{
    IdleWaiting, OnOff, Oracle, Policy, RandomizedSkiRental, Timeout, WindowedQuantile,
};
use idlewait::util::table::{fcount, fnum, Table};
use idlewait::util::units::Duration;

fn main() {
    idlewait::util::logging::init();
    let period_ms: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40.0);
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    let period = Duration::from_millis(period_ms);

    // --- fixed-period plan (the paper's analysis) ---
    let mut t = Table::new(&["strategy", "items", "lifetime (h)", "note"]).with_title(
        format!(
            "plan for periodic T_req = {period_ms} ms, budget {} J",
            cfg.workload.energy_budget.joules()
        ),
    );
    for kind in [
        PolicySpec::OnOff,
        PolicySpec::IdleWaiting,
        PolicySpec::IdleWaitingM1,
        PolicySpec::IdleWaitingM12,
    ] {
        let p = model.predict(kind, period);
        match p.n_max {
            Some(n) => {
                t.row(&[
                    kind.name().into(),
                    fcount(n),
                    fnum(p.lifetime.hours(), 2),
                    String::new(),
                ]);
            }
            None => {
                t.row(&[
                    kind.name().into(),
                    "—".into(),
                    "—".into(),
                    "infeasible: period < item latency".into(),
                ]);
            }
        }
    }
    print!("{}", t.render());

    let mut t = Table::new(&["idle mode", "crossover vs On-Off (ms)"])
        .with_title("break-even request periods");
    for (label, kind) in [
        ("baseline (134.3 mW)", PolicySpec::IdleWaiting),
        ("method 1 (34.2 mW)", PolicySpec::IdleWaitingM1),
        ("method 1+2 (24.0 mW)", PolicySpec::IdleWaitingM12),
    ] {
        t.row(&[
            label.into(),
            fnum(
                crossover::asymptotic(&model, model.item.idle_power(kind)).millis(),
                2,
            ),
        ]);
    }
    print!("{}", t.render());

    // --- irregular arrivals (paper §7 future work) ---
    // Poisson arrivals with the same mean: compare the fixed strategies,
    // the deployable online policies and the clairvoyant oracle bound.
    let mut items_cfg = cfg.clone();
    items_cfg.workload.max_items = Some(20_000);
    let oracle = Oracle::from_model(&model, PowerSaving::M12);
    let timeout = Timeout::from_model(&model, PowerSaving::M12);
    let mut t = Table::new(&["policy", "energy/item (mJ)", "configurations", "off gaps"])
        .with_title(format!(
            "poisson arrivals, mean {period_ms} ms (20k items; lower energy/item is better)"
        ));
    let oracle_label = oracle.label();
    let timeout_label = timeout.label();
    let rand_ski = RandomizedSkiRental::from_model(&model, PowerSaving::M12, None, 42);
    let rand_label = rand_ski.label();
    let mut policies: Vec<(&str, Box<dyn Policy>)> = vec![
        ("on-off", Box::new(OnOff)),
        ("idle-waiting (m1+2)", Box::new(IdleWaiting::method12())),
        (timeout_label.as_str(), Box::new(timeout)),
        (rand_label.as_str(), Box::new(rand_ski)),
        (oracle_label.as_str(), Box::new(oracle)),
    ];
    for (label, policy) in &mut policies {
        let mut arrivals = Poisson::new(period, Duration::from_millis(0.05), 42);
        let report = simulate(&items_cfg, policy.as_mut(), &mut arrivals);
        t.row(&[
            (*label).into(),
            fnum(report.energy_exact.millijoules() / report.items as f64, 4),
            report.configurations.to_string(),
            report.decisions.powered_off.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nthe oracle idles through short gaps and powers off for gaps beyond\n\
         its {:.0} ms crossover; the deployable ski-rental policies stay within\n\
         2x (deterministic) / e/(e-1) in expectation (randomized) of it without\n\
         seeing the future (the paper's future-work scenario).",
        crossover::asymptotic(&model, model.item.idle_power(PolicySpec::IdleWaitingM12))
            .millis()
    );

    // --- tunable sweep: which quantile should a deployment pick? ---
    // Sweep the windowed-quantile predictor's `quantile` knob (the
    // config `policy_params.quantile`) over a bursty IoT trace: low
    // quantiles track the dense bursts (idle-leaning), high quantiles
    // track the silences (off-leaning). The sweet spot depends on the
    // burst/silence mix — exactly why it is a tunable.
    let gaps = tracegen::generate_durations(TraceKind::BurstyIot, 256, period_ms, 7);
    let mut sweep_cfg = cfg.clone();
    sweep_cfg.workload.max_items = Some(2_000);
    let mut t = Table::new(&["quantile", "energy/item (mJ)", "idled", "off gaps"]).with_title(
        format!("windowed-quantile tunable sweep on a bursty IoT trace (nominal {period_ms} ms)"),
    );
    for quantile in [0.5, 0.75, 0.9, 0.99] {
        let mut policy = WindowedQuantile::from_model(&model, PowerSaving::M12, 64, quantile);
        let mut arrivals = TraceReplay::new(gaps.clone());
        let report = simulate(&sweep_cfg, &mut policy, &mut arrivals);
        t.row(&[
            fnum(quantile, 2),
            fnum(report.energy_exact.millijoules() / report.items as f64, 4),
            fcount(report.decisions.idled),
            fcount(report.decisions.powered_off),
        ]);
    }
    print!("{}", t.render());
}
