//! Configuration-parameter exploration (Experiment 1 as an application).
//!
//! Walks the full 66-point SPI sweep on both Spartan-7 devices, prints
//! the Fig 7 grids, and demonstrates the *practical* use of the sweep: a
//! deployment helper that picks the most energy-efficient configuration
//! settings subject to a power-budget ceiling (the paper notes the
//! fastest settings need a higher power budget — §5.2's closing caveat).
//!
//! ```sh
//! cargo run --release --example config_sweep
//! ```

use idlewait::config::schema::{FpgaModel, SpiConfig};
use idlewait::experiments::exp1;
use idlewait::util::table::{fnum, Table};

/// Pick the lowest-energy setting whose loading-stage power fits `cap_mw`.
fn best_under_power_cap(result: &exp1::Exp1Result, cap_mw: f64) -> Option<&exp1::SweepPoint> {
    result
        .points
        .iter()
        .filter(|p| p.profile.loading().power.milliwatts() <= cap_mw)
        .min_by(|a, b| {
            a.config_energy_mj()
                .partial_cmp(&b.config_energy_mj())
                .unwrap()
        })
}

fn main() {
    idlewait::util::logging::init();

    for model in [FpgaModel::Xc7s15, FpgaModel::Xc7s25] {
        let result = exp1::run(model);
        print!("{}", result.render_fig7());
        print!("{}", result.render_summary());
        println!();
    }

    // Deployment helper: optimal settings under decreasing power budgets.
    let result = exp1::run(FpgaModel::Xc7s15);
    let mut t = Table::new(&[
        "power cap (mW)",
        "best setting",
        "config energy (mJ)",
        "config time (ms)",
    ])
    .with_title("configuration choice under a loading-stage power budget");
    for cap in [500.0, 420.0, 380.0, 340.0, 325.0] {
        match best_under_power_cap(&result, cap) {
            Some(p) => {
                t.row(&[
                    fnum(cap, 0),
                    p.spi.label(),
                    fnum(p.config_energy_mj(), 2),
                    fnum(p.config_time_ms(), 1),
                ]);
            }
            None => {
                t.row(&[fnum(cap, 0), "none feasible".into(), "—".into(), "—".into()]);
            }
        }
    }
    print!("{}", t.render());

    // Sanity anchors from the paper.
    let opt = result.point(SpiConfig::optimal());
    println!(
        "\npaper anchor: optimal = {} -> {:.2} mJ / {:.2} ms (paper: 11.85 mJ / 36.15 ms)",
        SpiConfig::optimal().label(),
        opt.config_energy_mj(),
        opt.config_time_ms()
    );
}
