//! Quickstart: load the AOT-compiled LSTM accelerator, run one inference
//! through the PJRT runtime, and price a single workload item with the
//! energy model — the smallest end-to-end tour of the library.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};
use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::energy::analytical::Analytical;
use idlewait::energy::crossover;
use idlewait::runtime::inference::Variant;
use idlewait::util::units::Duration;

fn main() -> Result<()> {
    idlewait::util::logging::init();

    // 1. Load + compile the AOT artifacts (python never runs here).
    let runtime = idlewait::runtime::pool::default_runtime()
        .context("run `make artifacts` first")?;
    let max_err = runtime.self_check()?;
    println!("runtime self-check vs JAX: max |err| = {max_err:.2e}");

    // 2. One real inference on the self-check window.
    let window = runtime.manifest.selfcheck.window.clone();
    let result = runtime.forecast(&window, Variant::Forecast)?;
    println!(
        "forecast = {:.6} ({:.3} ms host latency on the CPU stand-in)",
        result.forecast,
        result.latency.millis()
    );

    // 3. Price one workload item with the paper's energy model (Table 2).
    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);
    println!(
        "\nenergy per workload item (Table 2 calibration):\n  \
         On-Off       : {:.3} mJ (config {:.2} mJ dominates)\n  \
         Idle-Waiting : {:.4} mJ active + {:.1} mW while idle",
        model.item.e_item_onoff().millijoules(),
        model.item.e_config.millijoules(),
        model.item.e_active.millijoules(),
        model.item.idle_power_baseline.milliwatts(),
    );

    // 4. The paper's core decision rule.
    let t40 = Duration::from_millis(40.0);
    let onoff = model.predict(PolicySpec::OnOff, t40);
    let iw = model.predict(PolicySpec::IdleWaiting, t40);
    println!(
        "\nat T_req = 40 ms within {} J:\n  On-Off       : {} items\n  Idle-Waiting : {} items ({:.2}x)",
        cfg.workload.energy_budget.joules(),
        onoff.n_max.unwrap(),
        iw.n_max.unwrap(),
        iw.n_max.unwrap() as f64 / onoff.n_max.unwrap() as f64
    );
    println!(
        "break-even request period: {:.2} ms (paper: 89.21 ms)",
        crossover::asymptotic(&model, model.item.idle_power_baseline).millis()
    );
    Ok(())
}
