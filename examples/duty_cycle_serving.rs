//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! A duty-cycle IoT deployment: the (simulated) RP2040 wakes every 40 ms
//! with a fresh 24×6 sensor window; the coordinator drives the Spartan-7
//! board model through the strategy's phases for energy accounting while
//! the *actual inference* executes the AOT-compiled Pallas/JAX LSTM on
//! the PJRT CPU client. Runs all four strategies back-to-back and prints
//! latency/throughput plus the projected battery lifetime for each —
//! reproducing the paper's 40 ms case study with live compute in the
//! loop. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example duty_cycle_serving
//! ```

use anyhow::{Context, Result};
use idlewait::config::paper_default;
use idlewait::config::schema::PolicySpec;
use idlewait::coordinator::requests::Periodic;
use idlewait::coordinator::server::{serve, ServerConfig};
use idlewait::energy::analytical::Analytical;
use idlewait::runtime::inference::Variant;
use idlewait::strategies::strategy::build;
use idlewait::util::table::{fcount, fnum, Table};
use idlewait::util::units::Duration;

const REQUESTS: u64 = 500;
const PERIOD_MS: f64 = 40.0;

fn main() -> Result<()> {
    idlewait::util::logging::init();
    let runtime = idlewait::runtime::pool::default_runtime()
        .context("run `make artifacts` first")?;
    runtime.self_check()?;

    let cfg = paper_default();
    let model = Analytical::new(&cfg.item, cfg.workload.energy_budget);

    let mut table = Table::new(&[
        "strategy",
        "requests",
        "configs",
        "p50 lat (ms)",
        "p95 lat (ms)",
        "deadline misses",
        "energy (mJ)",
        "mJ/request",
        "projected items in 4147 J",
        "projected lifetime (h)",
    ])
    .with_title(format!(
        "duty-cycle serving: {REQUESTS} real LSTM inferences at T_req = {PERIOD_MS} ms"
    ));

    for kind in [
        PolicySpec::OnOff,
        PolicySpec::IdleWaiting,
        PolicySpec::IdleWaitingM1,
        PolicySpec::IdleWaitingM12,
    ] {
        let mut policy = build(kind, &model);
        let mut arrivals = Periodic {
            period: Duration::from_millis(PERIOD_MS),
        };
        let server_cfg = ServerConfig {
            sim: &cfg,
            variant: Variant::Forecast,
            max_requests: REQUESTS,
        };
        let report = serve(&server_cfg, &runtime, policy.as_mut(), &mut arrivals)?;
        let summary = report.metrics.latency_summary().expect("latencies recorded");
        let e_mj = report.metrics.sim_energy.millijoules();
        let per_request = e_mj / report.metrics.requests as f64;
        // projection from measured per-request energy onto the battery
        let projected = (cfg.workload.energy_budget.millijoules() / per_request) as u64;
        let lifetime_h =
            Duration::from_millis(PERIOD_MS).hours() * projected as f64;
        table.row(&[
            kind.name().into(),
            report.metrics.requests.to_string(),
            report.configurations.to_string(),
            fnum(summary.p50, 3),
            fnum(summary.p95, 3),
            report.metrics.deadline_misses.to_string(),
            fnum(e_mj, 1),
            fnum(per_request, 4),
            fcount(projected),
            fnum(lifetime_h, 2),
        ]);
    }

    print!("{}", table.render());
    println!(
        "\npaper comparison at 40 ms: Idle-Waiting ≈2.23x On-Off items; \
         Methods 1+2 ≈12.39x On-Off lifetime.\n\
         (host latency is the CPU stand-in for the FPGA fabric; energy comes\n\
         from the calibrated board model — see DESIGN.md substitution ledger)"
    );
    Ok(())
}
